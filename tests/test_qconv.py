"""Quantized conv (paper's ResNet substrate): GEMM-lowering correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.precision_policy import BASELINE, PAPER_FP8
from repro.core.qconv import conv_init, qconv2d
from repro.models.resnet import ResNetConfig, init_resnet, resnet_loss


class TestQConv:
    @pytest.mark.parametrize("stride,padding", [((1, 1), "SAME"),
                                                ((2, 2), "SAME"),
                                                ((1, 1), "VALID")])
    def test_baseline_matches_lax_conv(self, stride, padding):
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 8, 3))
        w = conv_init(jax.random.PRNGKey(1), 3, 3, 3, 8)
        y = qconv2d(x, w, stride=stride, padding=padding, cfg=BASELINE)
        ref = jax.lax.conv_general_dilated(
            x.astype(jnp.bfloat16), w.astype(jnp.bfloat16), stride, padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            preferred_element_type=jnp.float32)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=2e-2, atol=2e-2)

    def test_fp8_conv_grads_finite(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 8, 3))
        w = conv_init(jax.random.PRNGKey(1), 3, 3, 3, 8)

        def loss(w):
            y = qconv2d(x, w, key=jax.random.PRNGKey(2), cfg=PAPER_FP8)
            return (y.astype(jnp.float32) ** 2).mean() * 100

        g = jax.grad(loss)(w)
        assert bool(jnp.isfinite(g).all()) and float(jnp.abs(g).sum()) > 0


class TestResNet:
    def test_forward_and_loss(self):
        cfg = ResNetConfig(depth_per_stage=(1, 1), widths=(8, 16))
        params = init_resnet(jax.random.PRNGKey(0), cfg)
        batch = {"image": jax.random.normal(jax.random.PRNGKey(1),
                                            (4, 16, 16, 3)),
                 "label": jnp.array([0, 1, 2, 3])}
        loss, m = resnet_loss(params, batch, cfg=cfg,
                              qkey=jax.random.PRNGKey(2))
        assert np.isfinite(float(loss))
        assert float(m["l2_loss"]) > 0

    def test_grad_step_trains(self):
        cfg = ResNetConfig(depth_per_stage=(1,), widths=(8,))
        params = init_resnet(jax.random.PRNGKey(0), cfg)
        batch = {"image": jax.random.normal(jax.random.PRNGKey(1),
                                            (8, 16, 16, 3)),
                 "label": jnp.arange(8) % 10}

        @jax.jit
        def step(p, k):
            (l, m), g = jax.value_and_grad(
                lambda p: resnet_loss(p, batch, cfg=cfg, qkey=k),
                has_aux=True)(p)
            p = jax.tree_util.tree_map(lambda a, b: a - 0.1 * b, p, g)
            return p, l

        l0 = None
        for i in range(10):
            params, l = step(params, jax.random.PRNGKey(i))
            l0 = l0 if l0 is not None else float(l)
        assert float(l) < l0   # overfits one batch
