"""Shared test fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the
single real CPU device; multi-device tests spawn subprocesses that set
xla_force_host_platform_device_count themselves."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
