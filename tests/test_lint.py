"""Precision-flow static analyzer: jaxpr traversal, VMEM model, lint
passes, and their wiring into the autotuner and spec builder.

The negative paths matter most here — a lint that can't fail is
decoration.  Each pass gets a test that plants the defect it exists to
catch (unfused fallback, unregistered/dead scale site, double-rounding
chain, oversized blocks) and asserts the expected finding comes out.
"""
import json

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import jaxpr_walk as jw
from repro.analysis import precision_lint as pl
from repro.analysis import vmem as vm


# ---------------------------------------------------------------------------
# jaxpr_walk: the canonical traversal
# ---------------------------------------------------------------------------

class TestJaxprWalk:
    def test_counts_through_scan(self):
        def f(x):
            return jax.lax.scan(lambda c, _: (c @ c, None), x, None,
                                length=3)[0]
        counts = jw.count_prims(jax.make_jaxpr(f)(jnp.ones((4, 4))))
        assert counts == {"pallas": 0, "outside_dot": 1}

    def test_all_eqns_sees_nested(self):
        def f(x):
            return jax.lax.cond(x.sum() > 0, lambda v: v * 2,
                                lambda v: v + 1, x)
        names = [e.primitive.name
                 for e in jw.all_eqns(jax.make_jaxpr(f)(jnp.ones(3)))]
        assert "cond" in names
        assert "mul" in names and "add" in names   # branch bodies walked

    def test_is_f8_rejects_uint8(self):
        assert jw.is_f8(jnp.float8_e5m2)
        assert jw.is_f8(jnp.float8_e4m3fn)
        assert not jw.is_f8(jnp.uint8)
        assert not jw.is_f8(jnp.bfloat16)

    def test_dtype_census(self):
        def f(x):
            return x.astype(jnp.float8_e5m2)
        census = jw.dtype_census(jax.make_jaxpr(f)(jnp.ones(8)))
        assert census["float8_e5m2"] == 1


# ---------------------------------------------------------------------------
# vmem: the analytic model
# ---------------------------------------------------------------------------

class TestVmemModel:
    def test_monotone_in_blocks(self):
        small = vm.gemm_vmem(128, 128, 128).total_bytes
        big = vm.gemm_vmem(256, 512, 256).total_bytes
        assert big > small
        assert vm.attn_vmem("fwd", 128, 512, 64).total_bytes \
            < vm.attn_vmem("fwd", 128, 1024, 64).total_bytes

    def test_defaults_fit(self):
        """Every built-in default block config must fit the model — the
        autotuner never prunes the default, so the model has to agree."""
        from repro.kernels.fused_quant_matmul import kernel as fk
        assert vm.gemm_vmem(fk.DEFAULT_BM, fk.DEFAULT_BK,
                            fk.DEFAULT_BN).fits
        for kind in ("fwd", "bwd"):
            assert vm.attn_vmem(kind, 128, 512, 128).fits

    def test_bwd_is_worst_case_over_kernels(self):
        est = vm.attn_vmem("bwd", 128, 512, 128)
        parts = (vm.attn_bwd_dq_vmem(128, 512, 128),
                 vm.attn_bwd_dkv_vmem(128, 512, 128))
        assert est.total_bytes == max(p.total_bytes for p in parts)

    def test_check_raises_with_modeled_footprint(self):
        with pytest.raises(ValueError) as ei:
            vm.check_attn_blocks(128, 32768, 128)
        msg = str(ei.value)
        est = vm.attn_fwd_vmem(128, 32768, 128)
        assert str(est.total_bytes) in msg      # the modeled bytes
        assert "attn_block_kv" in msg           # and the knob to shrink

    def test_prune_records_what_and_why(self):
        kept, pruned = vm.prune_attn_candidates(
            "bwd", [(128, 128), (128, 32768)], 128)
        assert kept == [(128, 128)]
        assert len(pruned) == 1
        assert pruned[0]["blocks"] == [128, 32768]
        assert pruned[0]["vmem_bytes"] > pruned[0]["budget_bytes"]
        assert "reason" in pruned[0]

    def test_budget_override(self):
        assert not vm.gemm_vmem(256, 512, 256, budget=1024).fits
        assert vm.gemm_vmem(256, 512, 256).fits


# ---------------------------------------------------------------------------
# autotune wiring: the sweep never times a pruned candidate
# ---------------------------------------------------------------------------

class TestAutotunePrefilter:
    def test_sweep_skips_pruned_candidates(self, monkeypatch):
        """With a tiny budget every non-default candidate is pruned: the
        report row records them and the timed `candidates` dict contains
        only the default."""
        from repro.kernels import autotune as at
        monkeypatch.setattr(vm, "VMEM_BYTES", 1)
        timed = []
        monkeypatch.setattr(
            at, "_bench", lambda fn, *a, **k: timed.append(1) or 1.0)
        table, report = at.sweep_gemm(shapes=[(256, 256, 256)],
                                      dims_list=("nn",), smoke=True,
                                      parity=False, log=lambda *a: None)
        row = report[0]
        assert len(row["candidates"]) == 1          # default only
        assert len(timed) == 1                      # one timing, not N
        assert row["pruned"], "pruned candidates must be recorded"
        for p in row["pruned"]:
            assert p["vmem_bytes"] > p["budget_bytes"] == 1
            blocks = "x".join(str(b) for b in p["blocks"])
            assert blocks not in row["candidates"]

    def test_sweep_attention_records_pruned(self, monkeypatch):
        from repro.kernels import autotune as at
        monkeypatch.setattr(vm, "VMEM_BYTES", 1)
        monkeypatch.setattr(at, "_bench", lambda fn, *a, **k: 1.0)
        monkeypatch.setattr(at, "_attn_parity",
                            lambda *a, **k: None)
        table, report = at.sweep_attention(shapes=[(256, 64)],
                                           kinds=("fwd",), smoke=True,
                                           parity=False,
                                           log=lambda *a: None)
        row = report[0]
        assert row["pruned"]                        # everything pruned
        assert list(row["candidates"]) \
            == [f"q{row['block_q']}_kv{row['block_kv']}"]  # default only

    def test_normal_budget_prunes_nothing_small(self):
        from repro.kernels import autotune as at
        kept, pruned = vm.prune_gemm_candidates(
            at.gemm_candidates(256, 256, 256,
                               defaults=(256, 512, 256), smoke=True))
        assert not pruned


# ---------------------------------------------------------------------------
# spec builder: oversized explicit knobs rejected at build time
# ---------------------------------------------------------------------------

def _smoke_specs(monkeypatch):
    import repro.launch.specs as S
    import repro.models.registry as R
    orig = R.build_config
    monkeypatch.setattr(
        R, "build_config",
        lambda a, smoke=False, **kw: orig(a, smoke=True, **kw))
    monkeypatch.setattr(S, "build_config", R.build_config)
    monkeypatch.setitem(S.SHAPES, "tiny_train",
                        dict(seq=64, batch=8, mode="train"))
    S._cfg_for_cell.cache_clear()
    return S


class TestSpecsVmemGate:
    def test_oversized_explicit_bkv_rejected(self, monkeypatch):
        S = _smoke_specs(monkeypatch)
        from repro.launch.mesh import make_mesh
        # resolve_block_kv caps bkv at the (padded) seq len, so shrink
        # the budget instead of inflating the knob past the cap.
        monkeypatch.setattr(vm, "VMEM_BYTES", 1)
        try:
            mesh = make_mesh((1, 1), ("data", "model"))
            with pytest.raises(ValueError, match="VMEM"):
                S.build_cell("qwen2-1.5b", "tiny_train", mesh,
                             overrides={"policy.quant.attn_block_kv": 128})
        finally:
            S._cfg_for_cell.cache_clear()

    def test_resolved_defaults_not_gated(self, monkeypatch):
        """No explicit knobs -> no VMEM gate on the resolved schedule
        (the autotuner table owns those; the lint's vmem_fit pass still
        checks them)."""
        S = _smoke_specs(monkeypatch)
        from repro.launch.mesh import enter_mesh, make_mesh
        monkeypatch.setattr(vm, "VMEM_BYTES", 1)
        try:
            mesh = make_mesh((1, 1), ("data", "model"))
            with enter_mesh(mesh):
                cell = S.build_cell("qwen2-1.5b", "tiny_train", mesh)
            assert "attn_block_q" in cell["meta"]
        finally:
            S._cfg_for_cell.cache_clear()

    def test_cell_config_matches_build_overrides(self, monkeypatch):
        S = _smoke_specs(monkeypatch)
        try:
            cfg = S.cell_config(
                "qwen2-1.5b", "tiny_train",
                overrides={"policy.quant.recipe": "hybrid",
                           "policy.quant.scaling": "delayed"})
            assert cfg.policy.quant.recipe == "hybrid"
            assert cfg.policy.quant.scaling == "delayed"
        finally:
            S._cfg_for_cell.cache_clear()


# ---------------------------------------------------------------------------
# lint passes: negative paths
# ---------------------------------------------------------------------------

def _tiny_lint_setup(monkeypatch):
    S = _smoke_specs(monkeypatch)
    from repro.launch.mesh import make_mesh
    return S, make_mesh((1, 1), ("data", "model"))


BASE_OV = {"policy.quant.scaling": "delayed",
           "policy.quant.backend": "pallas"}


class TestLintPasses:
    def test_clean_cell_no_errors(self, monkeypatch):
        """The tiny delayed cell lints clean under both recipes — the
        same invariant the CI gate enforces over the full zoo."""
        S, mesh = _tiny_lint_setup(monkeypatch)
        try:
            for recipe in ("paper_e5m2", "hybrid"):
                fs = pl.lint_cell(
                    "qwen2-1.5b", "tiny_train", mesh,
                    overrides={**BASE_OV, "policy.quant.recipe": recipe})
                errs = [f for f in fs if f.severity == "error"]
                assert not errs, [f.message for f in errs]
        finally:
            S._cfg_for_cell.cache_clear()

    def test_fuse_epilogue_off_yields_fallback_finding(self, monkeypatch):
        S, mesh = _tiny_lint_setup(monkeypatch)
        try:
            fs = pl.lint_cell(
                "qwen2-1.5b", "tiny_train", mesh,
                overrides={**BASE_OV, "policy.quant.recipe": "hybrid",
                           "policy.quant.fuse_epilogue": False})
        finally:
            S._cfg_for_cell.cache_clear()
        hits = [f for f in fs if f.pass_name == "fused_coverage"
                and "fuse_epilogue" in f.message]
        assert hits and hits[0].severity == "warning"

    def test_tampered_registry_fails_bijection(self, monkeypatch):
        """Dropping a registered site and adding a bogus one must each
        produce a site_bijection error."""
        S, mesh = _tiny_lint_setup(monkeypatch)
        import repro.launch.specs as _S
        from repro.scaling.calibrate import discover_lm_sites
        from repro.scaling.state import SiteRegistry
        try:
            cfg = S.cell_config(
                "qwen2-1.5b", "tiny_train",
                overrides={**BASE_OV, "policy.quant.recipe": "hybrid"})
            info = S.SHAPES["tiny_train"]
            from repro.models.transformer import init_lm
            params_s = jax.eval_shape(
                lambda: init_lm(jax.random.PRNGKey(0), cfg))
            batch_s = _S._token_batch(cfg, info["batch"], info["seq"],
                                      labels=True)
            good = discover_lm_sites(cfg, params_s, batch_s)
            fwd = [k for k in good.keys
                   if good.class_letter(k) in ("W", "A")]
            keys = [k for k in good.keys if k != fwd[0]] + ["bogus#siteW"]
            bad = SiteRegistry(
                keys, token_sites=good.token_sites,
                site_layers={k: n for k, n in good.n_rows.items()
                             if k in keys},
                token_site_layers=good.token_site_layers)
            fs = pl.site_passes(cfg, params_s, batch_s, "tampered",
                                registry=bad)
        finally:
            S._cfg_for_cell.cache_clear()
        msgs = [f.message for f in fs if f.pass_name == "site_bijection"
                and f.severity == "error"]
        assert any("unregistered" in m and fwd[0] in m for m in msgs), msgs
        assert any("dead" in m and "bogus#siteW" in m for m in msgs), msgs

    def test_double_rounding_detected(self):
        def bad(x):
            return x.astype(jnp.bfloat16).astype(jnp.float8_e5m2)
        jaxpr = jax.make_jaxpr(bad)(jnp.ones((8,), jnp.float32))
        fs = pl.double_rounding_pass(jaxpr, "toy")
        assert len(fs) == 1 and fs[0].severity == "error"
        assert fs[0].data["chain"] == ["float32", "bfloat16",
                                       "float8_e5m2"]

    def test_quantizer_is_single_rounding(self):
        """The real quantizer must NOT trip the double-rounding pass."""
        from repro.core.fp8_formats import E5M2
        from repro.core.quantize import quantize_rne
        jaxpr = jax.make_jaxpr(
            lambda x: quantize_rne(x, E5M2))(jnp.ones((8, 8), jnp.float32))
        assert pl.double_rounding_pass(jaxpr, "quantize_rne") == []

    def test_vmem_fit_flags_oversized_meta(self):
        from repro.launch.specs import cell_config
        cfg = cell_config("paper-transformer", "train_4k",
                          overrides={**BASE_OV,
                                     "policy.quant.recipe": "hybrid"})
        meta = {"mode": "train", "fuse_attention": True,
                "attn_block_q": 128, "attn_block_kv": 32768,
                "head_dim": 128, "seq": 4096, "batch": 32,
                "n_microbatches": 4, "d_model": cfg.d_model,
                "d_ff": cfg.d_ff}
        fs = pl.vmem_fit_pass(cfg, meta, "toy")
        assert any(f.pass_name == "vmem_fit" and f.severity == "error"
                   for f in fs)


# ---------------------------------------------------------------------------
# suppressions + report plumbing
# ---------------------------------------------------------------------------

class TestSuppressions:
    def test_downgrade_and_mark(self):
        f = pl.Finding("fused_coverage", "error", "a/b@hybrid", "boom x1")
        rules = [{"pass": "fused_coverage", "cell": "a/*",
                  "match": "boom", "max_severity": "warning",
                  "reason": "known fallback"}]
        out = pl.apply_suppressions([f], rules)
        assert out[0].severity == "warning" and out[0].suppressed
        assert out[0].suppressed_by == "known fallback"

    def test_never_upgrades_and_respects_cell_glob(self):
        f1 = pl.Finding("p", "info", "a/b@x", "m")
        f2 = pl.Finding("p", "error", "other/b@x", "m")
        rules = [{"pass": "p", "cell": "a/*", "max_severity": "warning",
                  "reason": "r"}]
        out = pl.apply_suppressions([f1, f2], rules)
        assert out[0].severity == "info" and not out[0].suppressed
        assert out[1].severity == "error" and not out[1].suppressed

    def test_rule_without_reason_rejected(self, tmp_path):
        p = tmp_path / "sup.json"
        p.write_text(json.dumps({"rules": [{"pass": "p"}]}))
        with pytest.raises(ValueError, match="reason"):
            pl.load_suppressions(p)

    def test_shipped_suppressions_load(self):
        for r in pl.load_suppressions():
            assert r["reason"]

    def test_markdown_report(self):
        fs = [pl.Finding("f8_payload", "error", "a/b@hybrid", "msg|pipe")]
        md = pl.to_markdown(fs)
        assert "a/b@hybrid" in md and "msg\\|pipe" in md
        assert "1 error(s)" in md
