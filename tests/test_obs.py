"""Precision-health telemetry (src/repro/obs + tools/healthdash).

The load-bearing law: enabling the counters (`QuantConfig.track_health`)
changes NO computed bits — loss, grads, master weights, and amax histories
are locked bit-identical counters-on vs counters-off, under both format
recipes, through the jitted train step and the fused attention kernel.
Plus: metrics pipeline (scalar/vector serialization, jsonl lifecycle),
anomaly detectors, forced-overflow / forced-saturation end-to-end runs,
healthdash rendering + schema validation, and straggler-EMA persistence
across checkpoint restarts.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.loss_scale import LossScaler
from repro.core.precision_policy import QuantConfig
from repro.obs.health import HealthConfig, HealthMonitor
from repro.obs.metrics import SCHEMA_VERSION, MetricsLogger, jsonable
from repro.obs.trace import Tracer
from repro.scaling import context as sc
from repro.scaling.state import DelayedScaling, SiteRegistry
from repro.tools import healthdash

jax.config.update("jax_platform_name", "cpu")

RECIPES = ("paper_e5m2", "hybrid")


# ---------------------------------------------------------------------------
# serialization + logger lifecycle
# ---------------------------------------------------------------------------

class TestJsonable:
    def test_scalars(self):
        assert jsonable(3) == 3
        assert jsonable(True) is True
        assert jsonable(1.5) == 1.5
        assert jsonable(np.float32(2.5)) == 2.5
        assert jsonable(jnp.asarray(7, jnp.int32)) == 7
        assert jsonable(float("nan")) == "nan"

    def test_vectors_do_not_raise(self):
        """The old loop coerced every metric with float(np.asarray(v)) and
        raised on vectors; jsonable must serialize them as (nested) lists."""
        v = jnp.arange(6, dtype=jnp.float32).reshape(3, 2)
        out = jsonable(v)
        assert out == [[0.0, 1.0], [2.0, 3.0], [4.0, 5.0]]
        json.dumps(out)  # round-trippable

    def test_dict_and_tuple(self):
        out = jsonable({"a": (jnp.ones(2), 1)})
        assert out == {"a": [[1.0, 1.0], 1]}


class TestMetricsLogger:
    def test_jsonl_sink_and_close(self, tmp_path):
        path = str(tmp_path / "m.jsonl")
        with MetricsLogger(path, meta={"arch": "t"}) as logger:
            for i in range(3):
                rec = logger.log({"step": i, "loss": 1.0 / (i + 1),
                                  "health/x#A": jnp.asarray([0.1, 0.2])})
            assert rec["v"] == SCHEMA_VERSION
        assert logger._f is None  # closed on context exit
        lines = [json.loads(l) for l in open(path)]
        assert len(lines) == 3
        assert all(l["v"] == SCHEMA_VERSION for l in lines)
        assert lines[0]["health/x#A"] == [pytest.approx(0.1),
                                          pytest.approx(0.2)]
        meta = json.loads((tmp_path / "m.jsonl.meta.json").read_text())
        assert meta["schema_version"] == SCHEMA_VERSION
        assert meta["arch"] == "t"

    def test_rolling_windows(self):
        logger = MetricsLogger(None, window=4)
        for i in range(10):
            logger.log({"t": float(i)})
        assert logger.values("t") == (6.0, 7.0, 8.0, 9.0)
        assert logger.mean("t") == 7.5
        assert logger.percentile("t", 50) == 7.5
        assert logger.mean("missing") is None

    def test_close_idempotent(self, tmp_path):
        logger = MetricsLogger(str(tmp_path / "m.jsonl"))
        logger.close()
        logger.close()


class TestTracer:
    def test_spans_and_export(self, tmp_path):
        path = str(tmp_path / "trace.json")
        tr = Tracer(path)
        with tr.span("data_wait", step=0):
            pass
        with tr.span("step_dispatch", step=0):
            pass
        d = tr.durations()
        assert set(d) == {"span/data_wait_s", "span/step_dispatch_s"}
        assert all(v >= 0 for v in d.values())
        assert tr.durations() == {}  # popped
        tr.export()
        trace = json.loads(open(path).read())
        evs = trace["traceEvents"]
        assert {e["name"] for e in evs} == {"data_wait", "step_dispatch"}
        assert all(e["ph"] == "X" for e in evs)


# ---------------------------------------------------------------------------
# anomaly detectors (unit)
# ---------------------------------------------------------------------------

def _kinds(events):
    return [e["kind"] for e in events]


class TestHealthMonitor:
    def test_overflow_fires_on_increment_only(self):
        mon = HealthMonitor()
        assert mon.observe(0, {"overflow_count": 0, "loss_scale": 8.0}) == []
        assert mon.observe(1, {"overflow_count": 0, "loss_scale": 8.0}) == []
        evs = mon.observe(2, {"overflow_count": 1, "loss_scale": 4.0})
        assert _kinds(evs) == ["overflow"]
        # count flat again: no event
        assert mon.observe(3, {"overflow_count": 1, "loss_scale": 4.0}) == []

    def test_scale_floor_event(self):
        scaler = LossScaler(mode="enhanced", init_scale=2.0 ** 17,
                            min_scale_schedule=((2, 65536.0),))
        mon = HealthMonitor(scaler=scaler)
        mon.observe(0, {"overflow_count": 0, "loss_scale": 131072.0})
        # overflow at step 3 lands the scale exactly on the scheduled floor
        evs = mon.observe(3, {"overflow_count": 1, "loss_scale": 65536.0})
        assert _kinds(evs) == ["overflow", "scale_floor"]
        assert evs[1]["value"] == 65536.0

    def test_no_floor_event_above_schedule(self):
        scaler = LossScaler(mode="enhanced", init_scale=2.0 ** 20,
                            min_scale_schedule=((2, 65536.0),))
        mon = HealthMonitor(scaler=scaler)
        mon.observe(0, {"overflow_count": 0, "loss_scale": 2.0 ** 20})
        evs = mon.observe(3, {"overflow_count": 1, "loss_scale": 2.0 ** 19})
        assert _kinds(evs) == ["overflow"]

    def test_loss_scale_flapping(self):
        mon = HealthMonitor(HealthConfig(flap_window=12, flap_min_changes=6,
                                         cooldown=100))
        kinds = []
        for i in range(12):
            scale = 1024.0 if i % 2 else 2048.0
            kinds += _kinds(mon.observe(i, {"loss_scale": scale}))
        assert "loss_scale_flapping" in kinds

    def test_site_counter_events(self):
        mon = HealthMonitor()
        evs = mon.observe(0, {"health/a#A": [0.5, 0.0],
                              "health/b#E": [0.0, 0.99],
                              "health/c#G": [0.5, 0.99],
                              "health/scale_churn": 0.1})
        got = {(e["kind"], e["site"]) for e in evs}
        assert got == {("saturation", "a#A"), ("underflow", "b#E"),
                       ("range_overflow", "c#G")}

    def test_per_layer_vector_reduces_with_max(self):
        mon = HealthMonitor()
        evs = mon.observe(0, {"health/stack#A": [[0.0, 0.0], [0.9, 0.0]]})
        assert _kinds(evs) == ["saturation"]
        assert evs[0]["value"] == pytest.approx(0.9)

    def test_cooldown_suppresses_repeats(self):
        mon = HealthMonitor(HealthConfig(cooldown=10))
        assert _kinds(mon.observe(0, {"health/a#A": [0.5, 0.0]})) \
            == ["saturation"]
        assert mon.observe(5, {"health/a#A": [0.5, 0.0]}) == []
        assert _kinds(mon.observe(10, {"health/a#A": [0.5, 0.0]})) \
            == ["saturation"]

    def test_stuck_and_nan_amax(self):
        mon = HealthMonitor(HealthConfig(stuck_window=3),
                            site_names=["s0", "s1"])
        kinds = []
        for i in range(5):
            kinds += [(e["kind"], e.get("site")) for e in
                      mon.observe(i, {"health/amax_sites": [2.0, float(i)]})]
        assert ("stuck_amax", "s0") in kinds
        assert all(s != "s1" for _, s in kinds)
        evs = mon.observe(6, {"health/amax_sites": [2.0, float("nan")]})
        assert ("nan_amax", "s1") in [(e["kind"], e.get("site"))
                                      for e in evs]

    def test_straggler_streak(self):
        mon = HealthMonitor(HealthConfig(straggler_streak=3))
        kinds = []
        for i, n in enumerate([0, 1, 2, 3, 3]):
            kinds += _kinds(mon.observe(i, {"stragglers": n}))
        assert kinds.count("straggler_streak") == 1


# ---------------------------------------------------------------------------
# schema validation + rendering
# ---------------------------------------------------------------------------

GOOD = [{"v": SCHEMA_VERSION, "step": 0, "step_time_s": 0.5, "loss": 2.0,
         "stragglers": 0, "health/a#A": [0.1, 0.2],
         "health/scale_churn": 0.25, "health/amax_sites": [1.0, 2.0],
         "span/data_wait_s": 0.01},
        {"v": SCHEMA_VERSION, "step": 1, "step_time_s": 0.4, "loss": 1.9,
         "stragglers": 0, "health/a#A": [[0.1, 0.2], [0.3, 0.4]],
         "health_events": [{"step": 1, "kind": "saturation",
                            "site": "a#A", "value": 0.3}]}]


class TestValidateAndRender:
    def test_good_records_pass(self):
        assert healthdash.validate_records(
            GOOD, {"schema_version": SCHEMA_VERSION}) == []

    def test_corrupted_records_flagged(self):
        bad = [dict(GOOD[0]), dict(GOOD[1])]
        bad[0]["health/a#A"] = [0.1, 0.2, 0.3]   # not a pair
        bad[1]["step"] = 0                        # not increasing
        bad[1]["v"] = 99                          # wrong version
        errors = healthdash.validate_records(bad, {"schema_version": 2})
        assert len(errors) == 4
        errors2 = healthdash.validate_records(
            [{"v": SCHEMA_VERSION, "health_events": [{"site": "x"}]}])
        assert any("step" in e for e in errors2)
        assert any("health_event" in e for e in errors2)

    def test_render_markdown(self):
        md = healthdash.render(GOOD, {"arch": "t", "recipe": "hybrid",
                                      "sites": ["a#A"]},
                               serve_stats={"requests": 3, "finished": 2,
                                            "active": 1, "max_batch": 4,
                                            "kv_slot_occupancy": 0.5,
                                            "decode_tokens": 10,
                                            "decode_tokens_per_s": 100.0,
                                            "prefill_latency_s":
                                                {"p50": 0.1, "p99": 0.2}})
        assert "a#A" in md and "saturation" in md and "Serving" in md
        assert "data_wait" in md

    def test_render_empty(self):
        assert "Empty" in healthdash.render([])


# ---------------------------------------------------------------------------
# end-to-end: jitted train step, counters on vs off — bit parity
# ---------------------------------------------------------------------------

def _tiny_cfg(recipe, track):
    from repro.configs import paper_transformer
    from repro.scaling.calibrate import _delayed_quant_model
    cfg = paper_transformer.smoke().replace(
        n_layers=1, n_encoder_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
        d_ff=64, vocab_size=64, max_seq_len=16)
    cfg = _delayed_quant_model(cfg)
    q = dataclasses.replace(cfg.policy.quant, recipe=recipe,
                            track_health=track)
    return cfg.replace(policy=dataclasses.replace(cfg.policy, quant=q))


def _train_bits(recipe, track, n_steps=3):
    """(losses, master leaves, amax history, last metrics) after n jitted
    delayed-scaling steps."""
    from repro.models.transformer import init_lm
    from repro.scaling.calibrate import discover_lm_sites
    from repro.train.step import make_optimizer_for, make_train_step

    cfg = _tiny_cfg(recipe, track)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    B, S = 2, 8
    proto = {"tokens": jnp.zeros((B, S), jnp.int32),
             "labels": jnp.zeros((B, S), jnp.int32),
             "enc_inputs": jnp.zeros((B, 4, cfg.d_model), jnp.float32)}
    registry = discover_lm_sites(cfg, params, proto)
    ds = DelayedScaling(registry, qcfg=cfg.policy.quant)
    opt = make_optimizer_for(cfg, learning_rate=1e-3)
    step = jax.jit(make_train_step(cfg, opt, scaling=ds))
    state, sstate = opt.init(params), ds.init()
    rng = np.random.default_rng(0)
    losses = []
    for i in range(n_steps):
        batch = {"tokens": jnp.asarray(rng.integers(0, 64, (B, S)),
                                       jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, 64, (B, S)),
                                       jnp.int32),
                 "enc_inputs": jnp.asarray(
                     rng.normal(size=(B, 4, cfg.d_model)), jnp.float32)}
        (state, sstate), m = step(state, sstate, batch, jax.random.PRNGKey(i))
        losses.append(np.asarray(m["loss"]))
    master = [np.asarray(x) for x in jax.tree_util.tree_leaves(state.master)]
    return losses, master, np.asarray(sstate.amax_history), m


@pytest.mark.parametrize("recipe", RECIPES)
def test_train_step_counters_bit_parity(recipe):
    """THE law: track_health changes no computed bits — losses, master
    weights and amax histories bit-identical on vs off; health keys are
    emitted only when on."""
    losses_off, master_off, hist_off, m_off = _train_bits(recipe, False)
    losses_on, master_on, hist_on, m_on = _train_bits(recipe, True)
    for a, b in zip(losses_off, losses_on):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(master_off, master_on):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(hist_off, hist_on)
    health_on = sorted(k for k in m_on if k.startswith("health/"))
    assert not any(k.startswith("health/") for k in m_off)
    assert "health/scale_churn" in health_on
    assert "health/amax_sites" in health_on
    # per-site pairs present with sane fractions
    pairs = [k for k in health_on
             if k not in ("health/scale_churn", "health/amax_sites")]
    assert pairs
    for k in pairs:
        arr = np.asarray(m_on[k])
        assert arr.shape[-1] == 2
        assert (arr >= 0).all() and (arr <= 1).all()


# ---------------------------------------------------------------------------
# end-to-end: fused attention kernel, counters on vs off — bit parity
# ---------------------------------------------------------------------------

def _sdpa_run(cfg, q, k, v):
    from repro.core.qattention import fp8_sdpa
    keys = sc.attention_keys("s")
    reg = SiteRegistry(list(keys.values()), ("s",))
    ds = DelayedScaling(reg, qcfg=cfg)
    state = ds.init()

    def loss(q, k, v, tokens):
        with ds.collect(state, tokens):
            o = fp8_sdpa(q, k, v, key=jax.random.PRNGKey(7), cfg=cfg,
                         sm_scale=0.125, site="s")
            aux = sc.drain_aux()
        return o.astype(jnp.float32).sum(), (o, aux)

    (_, (o, aux)), grads = jax.value_and_grad(
        loss, argnums=(0, 1, 2, 3), has_aux=True)(q, k, v, ds.zero_tokens())
    return o, grads, dict(aux)


@pytest.mark.parametrize("recipe", RECIPES)
def test_fused_attention_counters_bit_parity(recipe):
    """Counters ride the kernels' existing stripe loops: outputs, all three
    grads, the amax observations and the token amax channels are
    bit-identical with counting on vs off."""
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 64, 64), jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 64, 64), jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 64, 64), jnp.bfloat16)
    base = QuantConfig(recipe=recipe, scaling="delayed",
                       backend="pallas_interpret")
    o_off, g_off, aux_off = _sdpa_run(
        dataclasses.replace(base, track_health=False), q, k, v)
    o_on, g_on, aux_on = _sdpa_run(
        dataclasses.replace(base, track_health=True), q, k, v)
    np.testing.assert_array_equal(np.asarray(o_off), np.asarray(o_on))
    for a, b in zip(g_off[:3], g_on[:3]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # amax observations bit-identical
    amax_off = {k2: v2 for k2, v2 in aux_off.items()
                if k2.startswith("amax/")}
    for k2, v2 in amax_off.items():
        np.testing.assert_array_equal(np.asarray(v2),
                                      np.asarray(aux_on[k2]))
    # token cotangents: the 5 amax channels match; health pairs ride behind
    tok_off = g_off[3]["s"]
    tok_on = g_on[3]["s"]
    np.testing.assert_array_equal(np.asarray(tok_off)[:5],
                                  np.asarray(tok_on)[:5])
    # health fracs present only when on, all in [0, 1]
    h_on = {k2: np.asarray(v2) for k2, v2 in aux_on.items()
            if k2.startswith("health/")}
    assert len(h_on) == 5  # q/k/v/s/p forward sites
    assert not any(k2.startswith("health/") for k2 in aux_off)
    for arr in h_on.values():
        assert arr.shape == (2,)
        assert (arr >= 0).all() and (arr <= 1).all()


# ---------------------------------------------------------------------------
# forced-saturation synthetic run -> events -> dashboard
# ---------------------------------------------------------------------------

def test_forced_saturation_emits_event_and_renders():
    """Huge activations under unit initial scales saturate the format; the
    counter sees it, the monitor emits, healthdash renders."""
    from repro.core.qlinear import qeinsum
    cfg = QuantConfig(recipe="paper_e5m2", scaling="delayed",
                      track_health=True)
    a = jax.random.normal(jax.random.PRNGKey(0), (16, 32)) * 1e6
    b = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
    registry = SiteRegistry(sc.operand_keys("s", ("act", "weight")).values(),
                            ("s",))
    ds = DelayedScaling(registry, qcfg=cfg)
    with ds.collect(ds.init(), ds.zero_tokens()):
        qeinsum("mk,kn->mn", a, b, key=jax.random.PRNGKey(2), cfg=cfg,
                site="s")
        aux = sc.drain_aux()
    sat = np.asarray(aux["health/s#a.A"])
    assert sat[0] > 0.5  # most of `a` saturates e5m2 at unit scale
    record = {"step": 0, **{k2: jsonable(v2) for k2, v2 in aux.items()
                            if k2.startswith("health/")}}
    events = HealthMonitor().observe(0, record)
    assert any(e["kind"] in ("saturation", "range_overflow")
               and e["site"] == "s#a.A" for e in events)
    record["health_events"] = events
    md = healthdash.render([record])
    assert "s#a.A" in md


# ---------------------------------------------------------------------------
# forced-overflow loop run: exactly-once counting, events, vectors, schema
# ---------------------------------------------------------------------------

def _loop(tmp_path, total_steps, *, init_scale, metrics=None,
          n_microbatches=1, mode="dynamic"):
    from repro.data import DataConfig, synthetic_lm_batches
    from repro.models.registry import build_config
    from repro.train.loop import LoopConfig, TrainLoop
    from repro.train.step import make_optimizer_for
    cfg = build_config("qwen2-1.5b", smoke=True).replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=128, remat=False)
    opt = make_optimizer_for(cfg, name="adam", learning_rate=3e-3,
                             scaler=LossScaler(mode=mode,
                                               init_scale=init_scale))
    data = synthetic_lm_batches(DataConfig(
        vocab_size=128, seq_len=32, batch_size=8, seed=0))
    loop = LoopConfig(total_steps=total_steps, checkpoint_every=5,
                      checkpoint_dir=str(tmp_path / "ckpt"), log_every=100,
                      metrics_path=metrics, n_microbatches=n_microbatches,
                      trace_path=str(tmp_path / "trace.json"))
    return TrainLoop(cfg, opt, data, loop, seed=0)


def test_forced_overflow_counts_once_and_emits(tmp_path):
    """init_scale 2^127 makes the scaled loss overflow f32: the jitted step
    increments overflow_count by EXACTLY one per overflowing step (not per
    microbatch), the monitor attaches an overflow event, the stream
    validates, and healthdash renders it."""
    mpath = str(tmp_path / "m.jsonl")
    _loop(tmp_path, 6, init_scale=2.0 ** 127, metrics=mpath,
          n_microbatches=2).run()
    records, meta = healthdash.load_metrics(mpath)
    assert len(records) == 6
    # step 0 overflowed exactly once despite 2 microbatches
    assert records[0]["overflow_count"] == 1
    counts = [r["overflow_count"] for r in records]
    assert counts == sorted(counts)
    events = [e for r in records for e in r.get("health_events", [])]
    assert any(e["kind"] == "overflow" for e in events)
    # spans made it into the records
    assert all("span/step_dispatch_s" in r for r in records)
    assert healthdash.validate_records(records, meta) == []
    md = healthdash.render(records, meta)
    assert "overflow" in md
    # trace exported alongside
    trace = json.loads((tmp_path / "trace.json").read_text())
    assert trace["traceEvents"]


def test_quant_loop_vector_metrics_and_schema(tmp_path):
    """Satellite-b regression through the REAL loop: track_health emits
    vector metrics (health/amax_sites, per-site pairs) — the logger must
    serialize them (the old float() coercion raised), the stream must
    validate, and on_metrics must see every serialized record."""
    from repro.data import DataConfig, synthetic_lm_batches
    from repro.models.registry import build_config  # noqa: F401
    from repro.models.transformer import init_lm
    from repro.scaling.calibrate import discover_lm_sites
    from repro.train.loop import LoopConfig, TrainLoop
    from repro.train.step import make_optimizer_for

    cfg = _tiny_cfg("paper_e5m2", True)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    B, S = 2, 8
    proto = {"tokens": jnp.zeros((B, S), jnp.int32),
             "labels": jnp.zeros((B, S), jnp.int32),
             "enc_inputs": jnp.zeros((B, 4, cfg.d_model), jnp.float32)}
    registry = discover_lm_sites(cfg, params, proto)
    del params
    ds = DelayedScaling(registry, qcfg=cfg.policy.quant)
    opt = make_optimizer_for(cfg, name="adam", learning_rate=1e-3,
                             scaler=LossScaler(mode="dynamic",
                                               init_scale=128.0))

    def data_at(step):
        it = synthetic_lm_batches(DataConfig(
            vocab_size=64, seq_len=S, batch_size=B, seed=0),
            start_step=step)
        for batch in it:
            yield {"tokens": batch["tokens"], "labels": batch["labels"],
                   "enc_inputs": jnp.zeros((B, 4, cfg.d_model), jnp.float32)}

    mpath = str(tmp_path / "m.jsonl")
    seen = []
    loop = LoopConfig(total_steps=2, checkpoint_every=10,
                      checkpoint_dir=str(tmp_path / "ckpt"), log_every=100,
                      metrics_path=mpath)
    TrainLoop(cfg, opt, data_at, loop, seed=0, scaling=ds,
              on_metrics=lambda s, r: seen.append((s, r))).run()
    records, meta = healthdash.load_metrics(mpath)
    assert len(records) == 2 and len(seen) == 2
    assert seen[0][1] == records[0]
    assert isinstance(records[0]["health/amax_sites"], list)
    assert meta["track_health"] is True
    assert meta["sites"] == list(registry.keys)
    assert healthdash.validate_records(records, meta) == []
    healthdash.render(records, meta)


# ---------------------------------------------------------------------------
# straggler EMA persists across checkpoint restarts (satellite c)
# ---------------------------------------------------------------------------

def test_straggler_state_survives_restart(tmp_path):
    import time
    lp = _loop(tmp_path, 6, init_scale=128.0)
    lp.loop.straggler_factor = 1.5
    orig = lp._step_fn
    calls = {"n": 0}

    def slow(*a):
        calls["n"] += 1
        if calls["n"] == 5:
            time.sleep(0.4)
        return orig(*a)

    lp._step_fn = slow
    out1 = lp.run()
    assert out1["stragglers"] >= 1
    extra = lp.ckpt.manifest(6).get("extra")
    assert extra["stragglers"] == out1["stragglers"]
    assert extra["straggler_ema"] > 0
    # resume: count carries over instead of resetting to zero, and no new
    # stragglers are flagged against the restored (healthy) baseline
    lp2 = _loop(tmp_path, 8, init_scale=128.0)
    lp2.loop.straggler_factor = 1.5
    out2 = lp2.run()
    assert out2["last_step"] == 8
    assert out2["stragglers"] == out1["stragglers"]
