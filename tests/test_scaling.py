"""Delayed per-tensor scaling subsystem (repro.scaling).

Covers: ring-buffer history semantics, scaling-mode config plumbing,
delayed-vs-jit amax equivalence on a constant-amax stream, the hot-path
guarantee (no full-tensor amax reduction when quantizing under delayed
scaling), end-to-end delayed training on the paper transformer, calibration
freeze -> deterministic serving, ScaleState checkpoint round-trip, and the
cross-replica amax sync."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantize as Q
from repro.core.precision_policy import (DELAYED_FP8, PAPER_FP8, QuantConfig)
from repro.core.qlinear import qeinsum
from repro.scaling import context as sc
from repro.scaling.state import (DelayedScaling, ScaleState, ScalingConfig,
                                 SiteRegistry, amax_from_history,
                                 split_observations)

RNE_JIT = QuantConfig(scaling="jit_amax", act_rounding="rne",
                      error_rounding="rne", grad_rounding="rne",
                      saturate_bwd=True)
RNE_DELAYED = dataclasses.replace(RNE_JIT, scaling="delayed")


# ---------------------------------------------------------------------------
# config plumbing
# ---------------------------------------------------------------------------

class TestQuantConfigModes:
    def test_backcompat_shim(self):
        cfg = QuantConfig(amax_scale_fwd=True, amax_scale_bwd=True)
        assert cfg.scaling == "jit_amax"
        assert cfg.amax_for("act") and cfg.amax_for("error")

    def test_shim_respects_direction(self):
        cfg = QuantConfig(amax_scale_fwd=True)
        assert cfg.scaling == "jit_amax"
        assert cfg.amax_for("weight") and not cfg.amax_for("error")

    def test_delayed_never_jit_amax(self):
        assert not DELAYED_FP8.amax_for("act")
        assert DELAYED_FP8.delayed

    def test_paper_default_unchanged(self):
        assert PAPER_FP8.scaling == "none"
        assert not PAPER_FP8.amax_for("act")

    def test_unknown_mode_rejected(self):
        with pytest.raises(ValueError):
            QuantConfig(scaling="bogus")


# ---------------------------------------------------------------------------
# ring-buffer history
# ---------------------------------------------------------------------------

def _reg(keys=("s#a.A",), token_sites=()):
    return SiteRegistry(keys, token_sites)


class TestHistory:
    def test_ring_push_order(self):
        ds = DelayedScaling(_reg(), ScalingConfig(history_len=3, margin=1.0))
        st = ds.init()
        for v in [1.0, 2.0, 3.0, 4.0]:
            st = ds.update(st, {"s#a.A": jnp.float32(v)})
        np.testing.assert_array_equal(np.asarray(st.amax_history[0]),
                                      [4.0, 3.0, 2.0])
        assert int(st.step) == 4

    def test_policies(self):
        hist = jnp.asarray([[1.0, 4.0, 2.0]], jnp.float32)
        assert float(amax_from_history(
            hist, ScalingConfig(policy="max"))[0]) == 4.0
        assert float(amax_from_history(
            hist, ScalingConfig(policy="most_recent"))[0]) == 1.0
        ema = float(amax_from_history(
            hist, ScalingConfig(policy="ema", ema_decay=0.5))[0])
        assert 1.0 < ema < 4.0

    def test_scale_formula(self):
        ds = DelayedScaling(_reg(), ScalingConfig(history_len=2, margin=1.0),
                            qcfg=RNE_DELAYED)
        st = ds.update(ds.init(), {"s#a.A": jnp.float32(2.0)})
        assert float(st.scale[0]) == pytest.approx(2.0 / 57344.0)

    def test_unobserved_key_carries_forward(self):
        ds = DelayedScaling(_reg(("s#a.A", "s#b.W")),
                            ScalingConfig(history_len=2, margin=1.0))
        st = ds.update(ds.init(), {"s#a.A": jnp.float32(2.0),
                                   "s#b.W": jnp.float32(8.0)})
        st = ds.update(st, {"s#a.A": jnp.float32(2.0)})   # b unobserved
        np.testing.assert_array_equal(np.asarray(st.amax_history[1]),
                                      [8.0, 8.0])

    def test_empty_history_keeps_unit_scale(self):
        ds = DelayedScaling(_reg(("s#a.A", "s#b.W")),
                            ScalingConfig(history_len=2))
        st = ds.update(ds.init(), {"s#a.A": jnp.float32(2.0)})
        assert float(st.scale[1]) == 1.0     # never observed -> scale 1

    def test_overflow_guard_probes_upward(self):
        ds = DelayedScaling(_reg(("s#E",)),
                            ScalingConfig(history_len=2, margin=1.0,
                                          growth=2.0))
        st = ds.init()
        st = ds.update(st, {"s#E": jnp.float32(np.inf)})
        v = float(st.amax_history[0, 0])
        assert np.isfinite(v) and v == pytest.approx(2.0 * 57344.0)

    def test_saturation_growth(self):
        ds = DelayedScaling(_reg(), ScalingConfig(history_len=2, margin=1.0,
                                                  growth=2.0))
        st = ds.init()   # scale 1.0 -> cap 57344
        st = ds.update(st, {"s#a.A": jnp.float32(57344.0)})
        assert float(st.amax_history[0, 0]) == pytest.approx(2 * 57344.0)
        # carried-forward (unobserved) rows must NOT re-grow
        st2 = ds.update(st, {})
        np.testing.assert_allclose(np.asarray(st2.amax_history[0, 0]),
                                   np.asarray(st.amax_history[0, 0]))

    def test_state_is_pytree(self):
        st = ScaleState.create(3, 4)
        leaves = jax.tree_util.tree_leaves(st)
        assert len(leaves) == 3
        st2 = jax.tree_util.tree_map(lambda x: x, st)
        assert st2.amax_history.shape == (3, 4)


# ---------------------------------------------------------------------------
# delayed vs jit equivalence (constant-amax stream)
# ---------------------------------------------------------------------------

class TestDelayedVsJit:
    def test_bitwise_equal_after_warmup(self):
        # amaxes placed exactly on the fp8 grid, so the observed (quantized)
        # amax equals the true amax and one warmup step converges the
        # history-derived scale to the jit-amax scale exactly.
        a = jax.random.normal(jax.random.PRNGKey(0), (16, 32))
        b = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
        a = a.at[0, 0].set(4.0)    # amax = 4.0 (on-grid)
        b = b.at[0, 0].set(8.0)
        key = jax.random.PRNGKey(2)

        y_jit = qeinsum("mk,kn->mn", a, b, key=key, cfg=RNE_JIT)

        reg = sc.operand_keys("site", ("act", "weight"))
        registry = SiteRegistry(reg.values(), ("site",))
        ds = DelayedScaling(registry, ScalingConfig(margin=1.0, policy="max"),
                            qcfg=RNE_DELAYED)
        state = ds.init()

        def run_collect(state):
            with ds.collect(state, ds.zero_tokens()):
                y = qeinsum("mk,kn->mn", a, b, key=key, cfg=RNE_DELAYED,
                            site="site")
                obs = sc.drain_aux()
            observed = split_observations(obs, {}, registry)
            return y, ds.update(state, observed)

        _, state = run_collect(state)       # warmup: history <- true amaxes
        y_delayed, _ = run_collect(state)   # scales now == jit-amax scales
        np.testing.assert_array_equal(np.asarray(y_delayed),
                                      np.asarray(y_jit))

    def test_token_cotangent_normalized_by_use_count(self):
        """A site used N times accumulates the SUM of N per-use E/G amaxes
        in its token cotangent; split_observations must divide by the
        trace-time use count so history records the mean, not the sum."""
        a = jax.random.normal(jax.random.PRNGKey(0), (8, 16))
        b = jax.random.normal(jax.random.PRNGKey(1), (16, 16))
        registry = SiteRegistry(sc.operand_keys("s", ("act", "weight"))
                                .values(), ("s",))
        ds = DelayedScaling(registry, qcfg=RNE_DELAYED)
        state = ds.init()

        def loss(a, tokens, n_uses):
            with ds.collect(state, tokens):
                total = 0.0
                for _ in range(n_uses):   # same site, n_uses identical uses
                    total = total + qeinsum("mk,kn->mn", a, b,
                                            key=jax.random.PRNGKey(7),
                                            cfg=RNE_DELAYED, site="s").sum()
                sc.drain_aux()
            return total

        obs = {}
        for n in (1, 3):
            _, tg = jax.value_and_grad(loss, argnums=(0, 1))(
                a, ds.zero_tokens(), n)
            assert registry.token_uses["s"] == n
            obs[n] = split_observations({}, tg[1], registry)["s#E"]
        # dY is all-ones at every use (sum() cotangent), so the normalized
        # per-use E amax must not scale with the number of uses.
        assert float(obs[3]) == pytest.approx(float(obs[1]))

    def test_observed_amax_matches_input_amax_on_grid(self):
        x = jnp.zeros((8, 8), jnp.float32).at[3, 3].set(-16.0)
        w = jnp.eye(8, dtype=jnp.float32)
        registry = SiteRegistry(sc.operand_keys("s", ("act", "weight"))
                                .values(), ("s",))
        ds = DelayedScaling(registry, qcfg=RNE_DELAYED)
        with ds.collect(ds.init(), ds.zero_tokens()):
            qeinsum("mk,kn->mn", x, w, key=jax.random.PRNGKey(0),
                    cfg=RNE_DELAYED, site="s")
            obs = sc.drain_aux()
        assert float(obs["amax/s#a.A"]) == 16.0
        assert float(obs["amax/s#b.W"]) == 1.0


# ---------------------------------------------------------------------------
# hot path: no full-tensor amax reduction under delayed scaling
# ---------------------------------------------------------------------------

# The canonical traversal lives in repro.analysis.jaxpr_walk; the lint
# passes and these tests assert through the same walker.
from repro.analysis.jaxpr_walk import walk_eqns as _walk_eqns


def _wide_reduce_max_count(fn, *args):
    jaxpr = jax.make_jaxpr(fn)(*args)
    wide = (jnp.float32, jnp.float16, jnp.bfloat16, jnp.float64)
    n = 0
    for eqn in _walk_eqns(jaxpr.jaxpr):
        if eqn.primitive.name == "reduce_max" and \
                any(getattr(v.aval, "dtype", None) in
                    [jnp.dtype(d) for d in wide] for v in eqn.invars):
            n += 1
    return n


class TestHotPath:
    def test_delayed_has_no_wide_amax_reduce(self):
        """The jit-amax path reduces over the full bf16/f32 operand per
        quantize; the delayed path must not (its observation reduces over
        the 1-byte fp8 payload only)."""
        a = jax.random.normal(jax.random.PRNGKey(0), (16, 32))
        b = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
        key = jax.random.PRNGKey(2)
        registry = SiteRegistry(sc.operand_keys("s", ("act", "weight"))
                                .values(), ("s",))
        ds = DelayedScaling(registry, qcfg=RNE_DELAYED)
        state = ds.init()

        def delayed_fwd_bwd(a, b, tokens):
            with ds.collect(state, tokens):
                def f(a, b, tokens):
                    return qeinsum("mk,kn->mn", a, b, key=key,
                                   cfg=RNE_DELAYED, site="s").sum()
                return jax.grad(f, argnums=(0, 1, 2))(a, b, tokens)

        def jit_fwd_bwd(a, b):
            def f(a, b):
                return qeinsum("mk,kn->mn", a, b, key=key, cfg=RNE_JIT).sum()
            return jax.grad(f, argnums=(0, 1))(a, b)

        assert _wide_reduce_max_count(delayed_fwd_bwd, a, b,
                                      ds.zero_tokens()) == 0
        assert _wide_reduce_max_count(jit_fwd_bwd, a, b) > 0

    def test_inline_amax_scale_never_called(self, monkeypatch):
        """quantize.amax_scale is the just-in-time reduction; under delayed
        scaling it must never run during the traced step."""
        def boom(*a, **k):
            raise AssertionError("inline amax_scale called in delayed mode")
        monkeypatch.setattr(Q, "amax_scale", boom)
        a = jax.random.normal(jax.random.PRNGKey(0), (8, 16))
        b = jax.random.normal(jax.random.PRNGKey(1), (16, 4))
        registry = SiteRegistry(sc.operand_keys("s", ("act", "weight"))
                                .values(), ("s",))
        ds = DelayedScaling(registry, qcfg=RNE_DELAYED)
        with ds.collect(ds.init(), ds.zero_tokens()):
            y = qeinsum("mk,kn->mn", a, b, key=jax.random.PRNGKey(2),
                        cfg=RNE_DELAYED, site="s")
        assert np.isfinite(np.asarray(y)).all()


# ---------------------------------------------------------------------------
# end-to-end: paper transformer trains under delayed scaling
# ---------------------------------------------------------------------------

def _tiny_paper_cfg():
    from repro.configs import paper_transformer
    from repro.scaling.calibrate import _delayed_quant_model
    cfg = paper_transformer.smoke().replace(
        n_layers=2, n_encoder_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
        d_ff=128, vocab_size=128, max_seq_len=32)
    return _delayed_quant_model(cfg)


class TestDelayedTraining:
    def test_paper_transformer_20_steps_finite(self, monkeypatch):
        from repro.models.transformer import init_lm
        from repro.scaling.calibrate import discover_lm_sites
        from repro.train.step import make_optimizer_for, make_train_step

        # Hot-path guarantee holds for the full model trace too.
        def boom(*a, **k):
            raise AssertionError("inline amax_scale called in delayed mode")
        monkeypatch.setattr(Q, "amax_scale", boom)

        cfg = _tiny_paper_cfg()
        assert cfg.policy.quant.scaling == "delayed"
        params = init_lm(jax.random.PRNGKey(0), cfg)
        B, S = 2, 16
        proto = {"tokens": jnp.zeros((B, S), jnp.int32),
                 "labels": jnp.zeros((B, S), jnp.int32),
                 "enc_inputs": jnp.zeros((B, 8, cfg.d_model), jnp.float32)}
        registry = discover_lm_sites(cfg, params, proto)
        assert len(registry) > 30 and len(registry.token_sites) > 10
        ds = DelayedScaling(registry, qcfg=cfg.policy.quant)
        opt = make_optimizer_for(cfg, learning_rate=1e-3)
        step = jax.jit(make_train_step(cfg, opt, scaling=ds))
        state, sstate = opt.init(params), ds.init()
        rng = np.random.default_rng(0)
        losses = []
        for i in range(20):
            batch = {
                "tokens": jnp.asarray(rng.integers(0, 128, (B, S)), jnp.int32),
                "labels": jnp.asarray(rng.integers(0, 128, (B, S)), jnp.int32),
                "enc_inputs": jnp.asarray(
                    rng.normal(size=(B, 8, cfg.d_model)), jnp.float32)}
            (state, sstate), m = step(state, sstate, batch,
                                      jax.random.PRNGKey(i))
            losses.append(float(m["loss"]))
        assert all(np.isfinite(losses)), losses
        assert int(sstate.step) == 20
        # scales actually adapted away from the unit default
        scales = np.asarray(sstate.scale)
        assert (scales != 1.0).sum() > len(scales) // 2
        # observations never leak into the logged metrics
        assert not any(k.startswith("amax/") for k in m)


# ---------------------------------------------------------------------------
# calibrate -> freeze -> deterministic serving
# ---------------------------------------------------------------------------

def _serve_cfg():
    from repro.models.config import ModelConfig
    from repro.core.precision_policy import PrecisionPolicy
    pol = PrecisionPolicy(kv_cache_format="e5m2")
    return ModelConfig(arch="tiny", n_layers=2, d_model=64, n_heads=2,
                       n_kv_heads=2, d_ff=128, vocab_size=128,
                       max_seq_len=64, policy=pol, scan_layers=False)


class TestCalibratedServing:
    def test_freeze_and_bitwise_deterministic_decode(self):
        from repro.models.transformer import init_lm
        from repro.scaling.calibrate import calibrate, freeze
        from repro.serve.engine import ServeConfig, ServeEngine

        cfg = _serve_cfg()
        params = init_lm(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(1)
        batches = [{"tokens": jnp.asarray(rng.integers(0, 128, (2, 16)),
                                          jnp.int32)} for _ in range(4)]
        ds, state = calibrate(params, cfg, batches,
                              scaling_cfg=ScalingConfig(margin=1.0))
        frozen = freeze(ds, state)
        # forward W/A sites and the FP8 KV-cache sites are all calibrated
        assert any(k.endswith("kv/k#A") for k in frozen)
        assert any("#b.W" in k for k in frozen)
        non_unit = [v for v in frozen.values() if v != 1.0]
        assert len(non_unit) > len(frozen) // 2
        assert all(np.isfinite(v) and v > 0 for v in frozen.values())

        def generate():
            eng = ServeEngine(cfg, params, ServeConfig(max_batch=2,
                                                       max_len=48),
                              frozen_scales=frozen)
            uid = eng.add_request(np.array([3, 5, 7], np.int32),
                                  max_new_tokens=8)
            out = eng.run_to_completion()
            return out[uid]

        first, second = generate(), generate()
        assert first == second            # bitwise deterministic
        assert len(first) == 8

    def test_frozen_scales_round_trip_json(self, tmp_path):
        from repro.scaling.calibrate import load_frozen, save_frozen
        scales = {"decoder/layer_0/attn/wq#a.A": 0.125,
                  "decoder/layer_0/kv/k#A": 3.5e-4}
        save_frozen(tmp_path, scales)
        assert load_frozen(tmp_path) == scales

    def test_frozen_formats_round_trip_json(self, tmp_path):
        from repro.scaling.calibrate import (load_frozen,
                                             load_frozen_formats,
                                             save_frozen)
        scales = {"decoder/layer_0/attn/wq#a.A": 0.125,
                  "decoder/layer_0/attn/kv/k#A": 3.5e-4}
        formats = {"decoder/layer_0/attn/wq#a.A": "e4m3",
                   "decoder/layer_0/attn/kv/k#A": "e5m2"}
        save_frozen(tmp_path, scales, formats)
        assert load_frozen(tmp_path) == scales
        assert load_frozen_formats(tmp_path) == formats

    def test_legacy_frozen_file_has_no_formats(self, tmp_path):
        from repro.scaling.calibrate import load_frozen_formats, save_frozen
        save_frozen(tmp_path, {"s#a.A": 1.0})
        assert load_frozen_formats(tmp_path) == {}

    def test_engine_refuses_format_mismatch(self):
        """A scale calibrated for the e4m3 grid served on e5m2 would be
        silently 128x off — the engine must refuse at construction."""
        from repro.serve.engine import ServeConfig, ServeEngine
        from repro.models.transformer import init_lm
        cfg = _serve_cfg()   # paper recipe (e5m2 W/A), e5m2 KV cache
        params = init_lm(jax.random.PRNGKey(0), cfg)
        scales = {"decoder/layer_0/attn/wq#a.A": 0.25}
        with pytest.raises(ValueError, match="calibrated under"):
            ServeEngine(cfg, params, ServeConfig(max_batch=1, max_len=16),
                        frozen_scales=scales,
                        frozen_formats={"decoder/layer_0/attn/wq#a.A":
                                        "e4m3"})
        # KV sites validate against the policy's kv_cache_format
        with pytest.raises(ValueError, match="kv"):
            ServeEngine(cfg, params, ServeConfig(max_batch=1, max_len=16),
                        frozen_scales=scales,
                        frozen_formats={"decoder/layer_0/attn/kv/k#A":
                                        "e4m3"})
        # matching formats construct fine
        ServeEngine(cfg, params, ServeConfig(max_batch=1, max_len=16),
                    frozen_scales=scales,
                    frozen_formats={"decoder/layer_0/attn/wq#a.A": "e5m2",
                                    "decoder/layer_0/attn/kv/k#A": "e5m2"})

    def test_freeze_with_formats_matches_recipe(self):
        from repro.scaling.calibrate import freeze_with_formats
        from repro.scaling.state import DelayedScaling, SiteRegistry
        from repro.core.precision_policy import HYBRID_DELAYED_FP8
        reg = SiteRegistry(["s#a.A", "s#b.W", "s#E",
                            "dec/attn/kv/k#A"])
        ds = DelayedScaling(reg, qcfg=HYBRID_DELAYED_FP8)
        scales, formats = freeze_with_formats(ds, ds.init(), _serve_cfg())
        assert formats["s#a.A"] == formats["s#b.W"] == "e4m3"
        assert formats["dec/attn/kv/k#A"] == "e5m2"   # from the KV policy
        assert "s#E" not in scales and "s#E" not in formats

    def test_kv_scales_refuse_uncalibrated_frozen_sites(self):
        """Frozen serving with an FP8 KV cache whose kv/* sites were never
        calibrated must REFUSE instead of silently quantizing the cache
        with unit scales (the bug: _kv_scales defaulted to 1.0, burning a
        wrong constant into the jitted program)."""
        from repro.models.attention import _kv_scales
        from repro.scaling import context as scale_ctx
        cfg = _serve_cfg()   # e5m2 KV cache policy
        # frozen context WITHOUT the kv sites -> raise, naming the sites
        ctx = scale_ctx.frozen_context({"decoder/wq#a.A": 0.25})
        with scale_ctx.activate(ctx), scale_ctx.scope("decoder"):
            with pytest.raises(ValueError, match="kv/k#A"):
                _kv_scales(cfg)
        # with the kv sites present the frozen constants flow through
        good = {"decoder/kv/k#A": 0.5, "decoder/kv/v#A": 0.25}
        with scale_ctx.activate(scale_ctx.frozen_context(good)), \
                scale_ctx.scope("decoder"):
            assert _kv_scales(cfg) == (0.5, 0.25)
        # no FP8 KV cache -> no constraint, whatever the context holds
        cfg_nokv = _serve_cfg()
        pol = dataclasses.replace(cfg_nokv.policy, kv_cache_format=None)
        cfg_nokv = cfg_nokv.replace(policy=pol)
        with scale_ctx.activate(scale_ctx.frozen_context({})), \
                scale_ctx.scope("decoder"):
            assert _kv_scales(cfg_nokv) == (1.0, 1.0)
        # calibration/collection contexts keep the permissive unit default
        with scale_ctx.activate(scale_ctx.collect_context({}, {})), \
                scale_ctx.scope("decoder"):
            assert _kv_scales(cfg) == (1.0, 1.0)


# ---------------------------------------------------------------------------
# checkpoint round-trip
# ---------------------------------------------------------------------------

class TestCheckpointRoundTrip:
    def test_scale_state_through_checkpointer(self, tmp_path):
        from repro.checkpoint import Checkpointer
        reg = SiteRegistry(("a#a.A", "a#E"), ("a",))
        ds = DelayedScaling(reg, ScalingConfig(history_len=4))
        st = ds.update(ds.init(), {"a#a.A": jnp.float32(2.0),
                                   "a#E": jnp.float32(128.0)})
        ck = Checkpointer(tmp_path, async_save=False)
        ck.save(7, {"scales": st}, extra={"scale_keys": list(reg.keys)})
        proto = jax.eval_shape(lambda s: s, {"scales": ds.init()})
        restored, step = ck.restore(proto)
        assert step == 7
        np.testing.assert_array_equal(np.asarray(st.amax_history),
                                      np.asarray(restored["scales"]
                                                 .amax_history))
        np.testing.assert_array_equal(np.asarray(st.scale),
                                      np.asarray(restored["scales"].scale))
        assert ck.manifest(7)["extra"]["scale_keys"] == list(reg.keys)


# ---------------------------------------------------------------------------
# distributed amax sync
# ---------------------------------------------------------------------------

class TestAmaxSync:
    def test_pmax_sync_under_pmap(self):
        from repro.distributed.amax_sync import make_amax_sync
        sync = make_amax_sync("d")
        obs = jnp.asarray([[1.0, 5.0, 2.0]], jnp.float32)  # 1 device
        out = jax.pmap(sync, axis_name="d")(obs)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(obs))

    def test_update_applies_sync_hook(self):
        calls = []

        def fake_sync(v):
            calls.append(v.shape)
            return v * 2.0
        ds = DelayedScaling(_reg(), ScalingConfig(history_len=2, margin=1.0))
        st = ds.update(ds.init(), {"s#a.A": jnp.float32(2.0)},
                       sync=fake_sync)
        assert calls == [(1,)]
        assert float(st.amax_history[0, 0]) == 4.0

    def test_none_axis_means_no_sync(self):
        from repro.distributed.amax_sync import make_amax_sync
        assert make_amax_sync(None) is None


# ---------------------------------------------------------------------------
# launch/specs: recipe + delayed-scaling knobs reach the dry-run cells
# ---------------------------------------------------------------------------

class TestSpecsDelayedCell:
    def test_build_cell_accepts_recipe_and_delayed_knobs(self, monkeypatch):
        """build_cell with {'policy.quant.recipe': 'hybrid',
        'policy.quant.scaling': 'delayed'} discovers the site registry,
        threads a ScaleState arg through the step, and shape-infers the
        whole step (the same abstract proof the dry-run lowers)."""
        import repro.launch.specs as S
        import repro.models.registry as R
        from repro.launch.mesh import enter_mesh, make_mesh
        from repro.scaling.state import ScaleState

        orig = R.build_config
        monkeypatch.setattr(
            R, "build_config",
            lambda a, smoke=False, **kw: orig(a, smoke=True, **kw))
        monkeypatch.setattr(S, "build_config", R.build_config)
        monkeypatch.setitem(S.SHAPES, "tiny_train",
                            dict(seq=64, batch=8, mode="train"))
        S._cfg_for_cell.cache_clear()
        try:
            mesh = make_mesh((1, 1), ("data", "model"))
            with enter_mesh(mesh):
                cell = S.build_cell(
                    "qwen2-1.5b", "tiny_train", mesh,
                    overrides={"policy.quant.recipe": "hybrid",
                               "policy.quant.scaling": "delayed"})
        finally:
            S._cfg_for_cell.cache_clear()
        assert cell["meta"]["recipe"] == "hybrid"
        assert cell["meta"]["scaling"] == "delayed"
        assert cell["meta"]["scale_rows"] > 0
        # step signature: (state, scale_state, batch, key)
        assert len(cell["args"]) == 4
        assert isinstance(cell["args"][1], ScaleState)
        assert cell["donate_argnums"] == (0, 1)
        # scale-state rows match the discovered registry
        assert cell["args"][1].scale.shape == (cell["meta"]["scale_rows"],)

    def test_build_cell_default_unchanged(self, monkeypatch):
        import repro.launch.specs as S
        import repro.models.registry as R
        from repro.launch.mesh import enter_mesh, make_mesh
        orig = R.build_config
        monkeypatch.setattr(
            R, "build_config",
            lambda a, smoke=False, **kw: orig(a, smoke=True, **kw))
        monkeypatch.setattr(S, "build_config", R.build_config)
        monkeypatch.setitem(S.SHAPES, "tiny_train",
                            dict(seq=64, batch=8, mode="train"))
        S._cfg_for_cell.cache_clear()
        try:
            mesh = make_mesh((1, 1), ("data", "model"))
            with enter_mesh(mesh):
                cell = S.build_cell("qwen2-1.5b", "tiny_train", mesh)
        finally:
            S._cfg_for_cell.cache_clear()
        assert cell["meta"]["scaling"] == "none"
        assert len(cell["args"]) == 3


# ---------------------------------------------------------------------------
# fused kernel amax epilogue (interpret mode)
# ---------------------------------------------------------------------------

class TestFusedAmaxEpilogue:
    def test_with_amax_matches_reference(self):
        from repro.kernels.fused_quant_matmul import ops
        a = jax.random.normal(jax.random.PRNGKey(0), (64, 128)) \
            .astype(jnp.float8_e5m2)
        b = jax.random.normal(jax.random.PRNGKey(1), (128, 64)) \
            .astype(jnp.float8_e5m2)
        key = jax.random.PRNGKey(2)
        scale = jnp.asarray([2.0], jnp.float32)
        out, amax = ops.fused_quant_matmul(a, b, key, scale, rounding="rne",
                                           with_amax=True, interpret=True)
        out_ref = ops.fused_quant_matmul(a, b, key, scale, rounding="rne",
                                         interpret=True)
        np.testing.assert_array_equal(
            np.asarray(out).view(np.uint8), np.asarray(out_ref).view(np.uint8))
        expect = float(jnp.max(jnp.abs(out.astype(jnp.float32))) * 2.0)
        assert float(amax) == pytest.approx(expect)
