"""Format-parity suite: pins down E4M3/E5M2 behavior bit-for-bit.

Locks the format-parameterized quantization stack introduced with the hybrid
recipe:
 * exhaustive 256-bit-pattern round-trips for RNE and SR into BOTH formats
   (subnormals, signed zero, NaN/inf included) across the three
   implementations — pure-jnp ref oracle, Pallas kernel in interpret mode,
   and the XLA (core.quantize) path — all bit-for-bit,
 * saturate-vs-inf overflow semantics per tensor class under both recipes
   (e4m3 saturates forward; e5m2 errors/gradients propagate inf for the
   loss scaler; e4m3 overflow becomes NaN, having no inf encoding),
 * the `QuantConfig.recipe` knob and the hybrid end-to-end training
   acceptance (scanned transformer + delayed scaling, e4m3 W/A payloads),
 * hypothesis property tests (slow): SR unbiased in expectation, RNE error
   <= 0.5 ulp, for both formats.
"""
import dataclasses

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest
from hyputil import given, settings, st

from repro.core import fp8_formats as F
from repro.core import quantize as Q
from repro.core.precision_policy import (HYBRID_DELAYED_FP8, HYBRID_FP8,
                                         PAPER_FP8, QuantConfig)
from repro.kernels.fused_quant_matmul import (fused_quant_matmul,
                                              fused_quant_matmul_ref)
from repro.kernels.stochastic_round import (stochastic_round_fp8,
                                            stochastic_round_fp8_ref)
from repro.kernels.stochastic_round.kernel import sr_quantize_kernel

FMTS = [(F.E5M2, ml_dtypes.float8_e5m2), (F.E4M3, ml_dtypes.float8_e4m3fn)]
IDS = ["e5m2", "e4m3"]


def _patterns(mldt):
    """All 256 bit patterns of an fp8 format, as (uint8 bits, f32 values)."""
    bits = np.arange(256, dtype=np.uint8)
    return bits, bits.view(mldt).astype(np.float32)


def _bits_of(q) -> np.ndarray:
    return np.asarray(q).view(np.uint8)


# ---------------------------------------------------------------------------
# exhaustive 256-pattern round trips
# ---------------------------------------------------------------------------

class TestExhaustiveRoundTrip:
    @pytest.mark.parametrize("fmt,mldt", FMTS, ids=IDS)
    @pytest.mark.parametrize("saturate", [True, False])
    def test_rne_roundtrip_all_patterns(self, fmt, mldt, saturate):
        """RNE of every decodable value is the identity on its bit pattern
        (finite values exactly; NaN stays NaN; e5m2 inf survives only the
        non-saturating path)."""
        bits, vals = _patterns(mldt)
        q = Q.quantize_rne(jnp.asarray(vals), fmt, saturate=saturate)
        qb = _bits_of(q)
        finite = np.isfinite(vals)
        np.testing.assert_array_equal(qb[finite], bits[finite])
        nan = np.isnan(vals)
        assert np.isnan(np.asarray(q, np.float32)[nan]).all()
        inf = np.isinf(vals)
        if inf.any():   # e5m2 only; e4m3fn has no inf encodings
            # RNE preserves non-finite inputs in BOTH modes (saturation
            # applies to finite overflow only — an inf operand is already
            # a signal, not a rounding event).
            out = np.asarray(q, np.float32)[inf]
            assert np.isinf(out).all()
            np.testing.assert_array_equal(np.sign(out), np.sign(vals[inf]))

    @pytest.mark.parametrize("fmt,mldt", FMTS, ids=IDS)
    @pytest.mark.parametrize("rand", [0, 1, 77, 255])
    def test_sr_roundtrip_all_patterns_any_rand(self, fmt, mldt, rand):
        """On-grid values are fixed points of SR for EVERY random draw —
        the bit-twiddle only ever moves mass between the two neighbors of an
        off-grid value."""
        bits, vals = _patterns(mldt)
        r = jnp.full(vals.shape, rand, jnp.uint16)
        q = Q.sr_fp8_via_f16(jnp.asarray(vals), r, fmt, saturate=True)
        finite = np.isfinite(vals)
        np.testing.assert_array_equal(_bits_of(q)[finite], bits[finite])
        assert np.isnan(np.asarray(q, np.float32)[np.isnan(vals)]).all()

    @pytest.mark.parametrize("fmt,mldt", FMTS, ids=IDS)
    def test_sr_three_paths_bit_for_bit(self, fmt, mldt):
        """ref oracle vs Pallas-interpret kernel vs XLA path, same random
        bits: identical down to the bit pattern, for a wide log-uniform
        sweep plus every decodable fp8 value and the specials."""
        rng = np.random.default_rng(0)
        sweep = (rng.standard_normal(2048)
                 * np.exp2(rng.uniform(-20, 18, 2048))).astype(np.float32)
        _, grid = _patterns(mldt)
        specials = np.array([0.0, -0.0, np.inf, -np.inf, np.nan,
                             fmt.max_normal, -fmt.max_normal,
                             fmt.min_subnormal, -fmt.min_subnormal,
                             fmt.min_subnormal / 2], np.float32)
        x = np.concatenate([sweep, grid, specials])
        x = np.resize(x, (32, 128)).astype(np.float32)
        xj = jnp.asarray(x)
        rand8 = jax.random.bits(jax.random.PRNGKey(1), x.shape, jnp.uint8)
        scale = jnp.asarray([2.0], jnp.float32)
        for saturate in (True, False):
            kern = sr_quantize_kernel(xj, rand8, scale, fmt=fmt.name,
                                      saturate=saturate, interpret=True)
            ref = stochastic_round_fp8_ref(xj, rand8, scale, fmt=fmt.name,
                                           saturate=saturate)
            xla = jax.jit(
                lambda v, r: Q.sr_fp8_via_f16(
                    v.astype(jnp.float32) * (1.0 / scale[0]), r, fmt,
                    saturate=saturate))(xj, rand8)
            np.testing.assert_array_equal(_bits_of(kern), _bits_of(ref))
            np.testing.assert_array_equal(_bits_of(kern), _bits_of(xla))

    @pytest.mark.parametrize("fmt,mldt", FMTS, ids=IDS)
    def test_rne_bit_exact_vs_ml_dtypes_dense(self, fmt, mldt):
        """Correctly-rounded (single-rounding) RNE from f32 matches
        ml_dtypes bit-for-bit on a dense sweep emphasizing subnormals and
        binade edges."""
        rng = np.random.default_rng(7)
        x = np.concatenate([
            (rng.standard_normal(50_000)
             * np.exp2(rng.uniform(-24, 18, 50_000))),
            rng.uniform(-2 * fmt.min_normal, 2 * fmt.min_normal, 20_000),
        ]).astype(np.float32)
        ours = _bits_of(Q.quantize_rne(jnp.asarray(x), fmt, saturate=True))
        ref = np.clip(x, -fmt.max_normal, fmt.max_normal).astype(mldt)
        np.testing.assert_array_equal(ours, ref.view(np.uint8))

    @pytest.mark.parametrize("fmt,mldt", FMTS, ids=IDS)
    def test_signed_zero_round_trips(self, fmt, mldt):
        x = jnp.asarray([0.0, -0.0], jnp.float32)
        np.testing.assert_array_equal(
            _bits_of(Q.quantize_rne(x, fmt)), np.array([0x00, 0x80]))
        q = Q.sr_fp8_via_f16(x, jnp.full((2,), 255, jnp.uint16), fmt)
        np.testing.assert_array_equal(_bits_of(q), np.array([0x00, 0x80]))


# ---------------------------------------------------------------------------
# overflow semantics per tensor class
# ---------------------------------------------------------------------------

class TestOverflowPerClass:
    def test_e5m2_nonsaturating_overflow_is_inf(self):
        q = Q.quantize_rne(jnp.asarray([1e6, -1e6]), F.E5M2, saturate=False)
        out = np.asarray(q, np.float32)
        assert np.isinf(out).all() and out[0] > 0 > out[1]

    def test_e4m3_nonsaturating_overflow_is_nan(self):
        """e4m3fn has no inf encoding: overflow surfaces as NaN — still
        non-finite, still detectable by the loss scaler."""
        q = Q.quantize_rne(jnp.asarray([1e6, 470.0]), F.E4M3, saturate=False)
        assert np.isnan(np.asarray(q, np.float32)).all()

    def test_e4m3_sr_overflow_is_nan(self):
        q = Q.quantize_sr(jnp.full((256,), 1e6), F.E4M3,
                          jax.random.PRNGKey(0), saturate=False)
        assert np.isnan(np.asarray(q, np.float32)).all()

    @pytest.mark.parametrize("cfg,fwd_fmt,bwd_fmt", [
        (PAPER_FP8, F.E5M2, F.E5M2),
        (HYBRID_FP8, F.E4M3, F.E5M2),
    ], ids=["paper_e5m2", "hybrid"])
    def test_recipe_class_semantics(self, cfg, fwd_fmt, bwd_fmt):
        """Forward classes saturate at their format's ceiling; error/grad
        classes overflow to a non-finite value the loss scaler can see."""
        big = jnp.asarray([1e6], jnp.float32)
        for cls in ("weight", "act"):
            fmt = F.get_format(cfg.format_for(cls))
            assert fmt.name == fwd_fmt.name
            assert cfg.saturate_for(cls)
            q = Q.quantize_rne(big, fmt, saturate=cfg.saturate_for(cls))
            assert float(np.asarray(q, np.float32)[0]) == fmt.max_normal
        for cls in ("error", "grad"):
            fmt = F.get_format(cfg.format_for(cls))
            assert fmt.name == bwd_fmt.name
            assert not cfg.saturate_for(cls)
            q = Q.quantize_rne(big, fmt, saturate=cfg.saturate_for(cls))
            out = float(np.asarray(q, np.float32)[0])
            assert np.isinf(out) if fmt.has_inf else np.isnan(out)


# ---------------------------------------------------------------------------
# the recipe knob
# ---------------------------------------------------------------------------

class TestRecipeKnob:
    def test_hybrid_sets_formats(self):
        cfg = QuantConfig(recipe="hybrid")
        assert cfg.fwd_format == "e4m3" and cfg.bwd_format == "e5m2"
        assert cfg.saturate_fwd and not cfg.saturate_bwd

    def test_paper_recipe_unchanged(self):
        assert PAPER_FP8.recipe == "paper_e5m2"
        assert PAPER_FP8.fwd_format == PAPER_FP8.bwd_format == "e5m2"

    def test_unknown_recipe_rejected(self):
        with pytest.raises(ValueError):
            QuantConfig(recipe="fp4")

    def test_recipe_survives_replace(self):
        """dataclasses.replace / eval_mode re-run __post_init__; the hybrid
        formats must be stable under it."""
        ev = HYBRID_FP8.eval_mode()
        assert ev.fwd_format == "e4m3" and ev.bwd_format == "e5m2"
        assert ev.recipe == "hybrid"
        d = dataclasses.replace(HYBRID_FP8, scaling="delayed")
        assert d.fwd_format == "e4m3" and d.delayed

    def test_recipe_owns_formats_both_ways(self):
        """Switching a hybrid config back to the paper recipe re-pins BOTH
        formats to e5m2 — the recipe label and the formats can never
        disagree."""
        back = dataclasses.replace(HYBRID_FP8, recipe="paper_e5m2")
        assert back.fwd_format == "e5m2" and back.bwd_format == "e5m2"
        fwd = dataclasses.replace(PAPER_FP8, recipe="hybrid")
        assert fwd.fwd_format == "e4m3" and fwd.bwd_format == "e5m2"

    def test_recipe_table(self):
        t = HYBRID_FP8.recipe_table()
        assert t["weight"] == dict(format="e4m3", rounding="rne",
                                   saturate=True)
        assert t["act"] == dict(format="e4m3", rounding="sr", saturate=True)
        assert t["error"] == dict(format="e5m2", rounding="sr",
                                  saturate=False)
        assert t["grad"] == dict(format="e5m2", rounding="sr",
                                 saturate=False)

    def test_hybrid_delayed_preset(self):
        assert HYBRID_DELAYED_FP8.delayed
        assert HYBRID_DELAYED_FP8.fwd_format == "e4m3"

    def test_registry_scale_targets_format_aware(self):
        """Under the hybrid recipe, W/A rows target the e4m3 ceiling (448)
        and E/G rows the e5m2 ceiling (57344)."""
        from repro.scaling.state import SiteRegistry
        reg = SiteRegistry(["s#a.A", "s#b.W", "s#E", "s#G"])
        v = {k: f for k, f in zip(reg.keys,
                                  reg.fmt_max_vector(HYBRID_FP8))}
        assert v["s#a.A"] == v["s#b.W"] == 448.0
        assert v["s#E"] == v["s#G"] == 57344.0
        assert reg.format_for("s#a.A", HYBRID_FP8) == "e4m3"
        assert reg.format_for("s#E", HYBRID_FP8) == "e5m2"


# ---------------------------------------------------------------------------
# format-parameterized kernels
# ---------------------------------------------------------------------------

class TestKernelFormats:
    @pytest.mark.parametrize("fmt_name", ["e5m2", "e4m3"])
    @pytest.mark.parametrize("rounding", ["rne", "sr"])
    def test_fused_matmul_matches_ref(self, fmt_name, rounding):
        m, k, n = 32, 256, 128
        a = (jax.random.normal(jax.random.PRNGKey(0), (m, k)) * 0.25).astype(
            jnp.float8_e5m2)
        b = (jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.1).astype(
            jnp.float8_e5m2)
        key = jax.random.PRNGKey(2)
        y = fused_quant_matmul(a, b, key, jnp.array([2.0]), bm=32, bk=128,
                               bn=128, out_format=fmt_name,
                               rounding=rounding, interpret=True)
        assert y.dtype == F.get_format(fmt_name).dtype
        rand8 = jax.random.bits(key, (m, n), jnp.uint8) if rounding == "sr" \
            else jnp.zeros((m, n), jnp.uint8)
        ref = fused_quant_matmul_ref(a, b, rand8, jnp.array([2.0]),
                                     out_format=fmt_name, rounding=rounding)
        np.testing.assert_array_equal(_bits_of(y), _bits_of(ref))

    @pytest.mark.parametrize("fmt_name", ["e5m2", "e4m3"])
    def test_sr_wrapper_any_rank(self, fmt_name):
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 128))
        out = stochastic_round_fp8(x, jax.random.PRNGKey(1), fmt=fmt_name,
                                   interpret=True)
        assert out.shape == x.shape
        assert out.dtype == F.get_format(fmt_name).dtype

    def test_back_compat_aliases(self):
        """The old e5m2-hardwired names remain importable and bit-identical
        to the format-generic implementations."""
        from repro.kernels.stochastic_round import (stochastic_round_e5m2,
                                                    stochastic_round_e5m2_ref)
        x = jax.random.normal(jax.random.PRNGKey(0), (16, 128)) * 8
        key = jax.random.PRNGKey(1)
        old = stochastic_round_e5m2(x, key, interpret=True)
        new = stochastic_round_fp8(x, key, fmt="e5m2", interpret=True)
        np.testing.assert_array_equal(_bits_of(old), _bits_of(new))
        rand8 = jax.random.bits(key, x.shape, jnp.uint8)
        s = jnp.ones((1,), jnp.float32)
        np.testing.assert_array_equal(
            _bits_of(stochastic_round_e5m2_ref(x, rand8, s)),
            _bits_of(stochastic_round_fp8_ref(x, rand8, s, fmt="e5m2")))
        h = jax.lax.bitcast_convert_type(x.astype(jnp.float16), jnp.uint16)
        np.testing.assert_array_equal(
            np.asarray(Q.sr_e5m2_from_bits(h, rand8)),
            np.asarray(Q.sr_fp8_from_bits(h, rand8, F.E5M2)))


# ---------------------------------------------------------------------------
# acceptance: hybrid recipe trains a scanned transformer w/ delayed scaling
# ---------------------------------------------------------------------------

def _tiny_cfg(quant: QuantConfig):
    from repro.core.precision_policy import PrecisionPolicy
    from repro.models.config import ModelConfig
    return ModelConfig(arch="t", n_layers=4, d_model=32, n_heads=2,
                       n_kv_heads=2, d_ff=64, vocab_size=64, max_seq_len=32,
                       policy=PrecisionPolicy(quant=quant), remat=False,
                       scan_layers=True)


def _train_delayed(quant: QuantConfig, steps: int = 30, seed: int = 0):
    from repro.models.transformer import init_lm
    from repro.scaling import DelayedScaling, discover_lm_sites
    from repro.train.step import make_optimizer_for, make_train_step
    cfg = _tiny_cfg(quant)
    params = init_lm(jax.random.PRNGKey(seed), cfg)
    B, S = 4, 16
    proto = {"tokens": jnp.zeros((B, S), jnp.int32),
             "labels": jnp.zeros((B, S), jnp.int32)}
    registry = discover_lm_sites(cfg, params, proto)
    ds = DelayedScaling(registry, qcfg=quant)
    opt = make_optimizer_for(cfg, learning_rate=3e-3)
    step = jax.jit(make_train_step(cfg, opt, scaling=ds))
    state, sstate = opt.init(params), ds.init()
    rng = np.random.default_rng(seed)
    data = [jnp.asarray(rng.integers(0, 64, (B, S)), jnp.int32)
            for _ in range(4)]
    losses = []
    for i in range(steps):
        toks = data[i % len(data)]   # small fixed set => memorizable
        (state, sstate), m = step(state, sstate,
                                  {"tokens": toks, "labels": toks},
                                  jax.random.PRNGKey(i))
        losses.append(float(m["loss"]))
    return np.asarray(losses), registry, sstate


class TestHybridTrainingAcceptance:
    def test_hybrid_trains_within_noise_of_e5m2(self):
        hybrid = QuantConfig(recipe="hybrid", scaling="delayed")
        paper = QuantConfig(scaling="delayed")
        lh, reg, sstate = _train_delayed(hybrid)
        lp, _, _ = _train_delayed(paper)
        assert np.isfinite(lh).all() and np.isfinite(lp).all()
        # both recipes learn...
        assert lh[-5:].mean() < lh[0] and lp[-5:].mean() < lp[0]
        # ...to within noise of each other
        assert abs(lh[-5:].mean() - lp[-5:].mean()) \
            < 0.15 * max(lh[-5:].mean(), lp[-5:].mean()), (lh[-5:], lp[-5:])
        # per-layer (not per-stack-position) sites: scanned sites own
        # n_groups rows each, and the trained scales differ across layers
        stacked = {k: n for k, n in reg.n_rows.items() if n > 1}
        assert stacked and all(n == 4 for n in stacked.values())
        sc = np.asarray(sstate.scale)
        distinct = sum(
            len(np.unique(sc[reg.index[k]:reg.index[k] + n])) > 1
            for k, n in stacked.items())
        assert distinct > len(stacked) // 2

    def test_hybrid_uses_e4m3_payloads(self):
        """The hybrid loss trace materializes BOTH storage dtypes: e4m3 for
        the forward W/A payloads, e5m2 for E/G."""
        from repro.models.transformer import init_lm, lm_loss
        hybrid = QuantConfig(recipe="hybrid", scaling="delayed")
        cfg = _tiny_cfg(hybrid)
        params = init_lm(jax.random.PRNGKey(0), cfg)
        toks = jnp.zeros((2, 16), jnp.int32)
        batch = {"tokens": toks, "labels": toks}

        def loss(p):
            return lm_loss(p, batch, cfg=cfg, qkey=jax.random.PRNGKey(0))[0]

        jaxpr = jax.make_jaxpr(jax.grad(loss))(params)
        dtypes = set()

        def walk(jx):
            for eqn in jx.eqns:
                for v in eqn.outvars:
                    d = getattr(v.aval, "dtype", None)
                    if d is not None:
                        dtypes.add(d)
                for sub in jax.tree_util.tree_leaves(
                        eqn.params, is_leaf=lambda x: hasattr(x, "jaxpr")):
                    if hasattr(sub, "jaxpr"):
                        walk(sub.jaxpr)
        walk(jaxpr.jaxpr)
        assert jnp.dtype(jnp.float8_e4m3fn) in dtypes
        assert jnp.dtype(jnp.float8_e5m2) in dtypes


# ---------------------------------------------------------------------------
# property tests (slow): SR unbiasedness + RNE half-ulp, both formats
# ---------------------------------------------------------------------------

def _rand_enumeration(fmt):
    """Every random draw the bit-twiddle distinguishes for `fmt`."""
    return jnp.arange(1 << Q.sr_spec(fmt).drop_bits, dtype=jnp.uint16)


@pytest.mark.slow
class TestSRUnbiasedProperty:
    @settings(max_examples=60, deadline=None)
    @given(st.floats(min_value=-4e4, max_value=4e4,
                     allow_nan=False, allow_infinity=False))
    def test_e5m2_unbiased_exact_expectation(self, val):
        """E[SR(x)] over the FULL random-bit enumeration equals the fp16
        pre-rounding of x exactly — unbiasedness as an identity, not a
        sampling bound."""
        self._check(F.E5M2, val)

    @settings(max_examples=60, deadline=None)
    @given(st.floats(min_value=-440.0, max_value=440.0,
                     allow_nan=False, allow_infinity=False))
    def test_e4m3_unbiased_exact_expectation(self, val):
        self._check(F.E4M3, val)

    def _check(self, fmt, val):
        spec = Q.sr_spec(fmt)
        r = _rand_enumeration(fmt)
        x = jnp.full(r.shape, val, jnp.float32)
        q = np.asarray(Q.sr_fp8_via_f16(x, r, fmt, saturate=True),
                       np.float32).astype(np.float64)
        # the twiddle's reference point: x clamped to the format range and
        # RNE'd onto the (prescaled) fp16 grid
        ref = np.clip(np.float64(val), -fmt.max_normal, fmt.max_normal)
        ref = float(np.float16(ref * 2.0 ** spec.pre_exp)) \
            * 2.0 ** -spec.pre_exp
        assert abs(q.mean() - ref) <= 1e-7 * max(1.0, abs(ref)), \
            (q.mean(), ref)


@pytest.mark.slow
class TestRNEHalfUlpProperty:
    @settings(max_examples=120, deadline=None)
    @given(st.floats(min_value=-5.7e4, max_value=5.7e4,
                     allow_nan=False, allow_infinity=False))
    def test_e5m2_half_ulp(self, val):
        self._check(F.E5M2, val)

    @settings(max_examples=120, deadline=None)
    @given(st.floats(min_value=-448.0, max_value=448.0,
                     allow_nan=False, allow_infinity=False))
    def test_e4m3_half_ulp(self, val):
        self._check(F.E4M3, val)

    def _check(self, fmt, val):
        q = float(np.asarray(
            Q.quantize_rne(jnp.asarray([val], jnp.float32), fmt),
            np.float32)[0])
        e = int(np.floor(np.log2(abs(val)))) if val != 0 else fmt.min_exp
        ulp = 2.0 ** (max(e, fmt.min_exp) - fmt.man_bits)
        assert abs(q - val) <= 0.5 * ulp + 1e-30, (val, q, ulp)
