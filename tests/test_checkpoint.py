"""Checkpointer: roundtrip, atomic commit, GC, elastic restore."""
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer


@pytest.fixture()
def tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "nested": {"b": jnp.ones((2,), jnp.bfloat16),
                       "c": jnp.asarray(3, jnp.int32)}}


def test_roundtrip(tmp_path, tree):
    ck = Checkpointer(tmp_path, async_save=False)
    ck.save(5, tree)
    proto = jax.eval_shape(lambda t: t, tree)
    restored, step = ck.restore(proto)
    assert step == 5
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_async_save(tmp_path, tree):
    ck = Checkpointer(tmp_path, async_save=True)
    ck.save(1, tree)
    ck.wait()
    assert ck.latest_step() == 1


def test_uncommitted_checkpoint_ignored(tmp_path, tree):
    ck = Checkpointer(tmp_path, async_save=False)
    ck.save(1, tree)
    # simulate a crash mid-save at step 2: directory without COMMITTED
    broken = Path(tmp_path) / "step_0000000002"
    broken.mkdir()
    (broken / "leaves.npz").write_bytes(b"garbage")
    assert ck.latest_step() == 1
    restored, step = ck.restore(jax.eval_shape(lambda t: t, tree))
    assert step == 1


def test_keep_last_k(tmp_path, tree):
    ck = Checkpointer(tmp_path, keep_last_k=2, async_save=False)
    for s in [1, 2, 3, 4]:
        ck.save(s, tree)
    assert ck.all_steps() == [3, 4]


def test_shape_mismatch_raises(tmp_path, tree):
    ck = Checkpointer(tmp_path, async_save=False)
    ck.save(1, tree)
    bad = dict(tree)
    bad["a"] = jnp.zeros((5, 5))
    with pytest.raises(ValueError, match="shape"):
        ck.restore(jax.eval_shape(lambda t: t, bad))


def test_missing_leaf_raises(tmp_path, tree):
    ck = Checkpointer(tmp_path, async_save=False)
    ck.save(1, tree)
    bigger = dict(tree)
    bigger["extra"] = jnp.zeros((2,))
    with pytest.raises(KeyError):
        ck.restore(jax.eval_shape(lambda t: t, bigger))


def test_manifest(tmp_path, tree):
    ck = Checkpointer(tmp_path, async_save=False)
    ck.save(7, tree, extra={"loss": 1.5})
    m = ck.manifest()
    assert m["step"] == 7 and m["extra"]["loss"] == 1.5
    assert "a" in m["keys"]


def test_key_escape_collision(tmp_path):
    # Regression: under the v1 scheme ("/" -> "__") a leaf literally
    # named "w__gate" and a nested path "w/gate" mangled to the same
    # archive name — one silently overwrote the other. The v2 escape
    # ("_" -> "_u" first) keeps them distinct and round-trips exactly.
    tree = {"w__gate": jnp.full((2,), 1.0, jnp.float32),
            "w": {"gate": jnp.full((2,), 2.0, jnp.float32)},
            "under_score": {"x__y": jnp.full((2,), 3.0, jnp.float32)}}
    ck = Checkpointer(tmp_path, async_save=False)
    ck.save(1, tree)
    man = ck.manifest()
    assert man["key_escape"] == "v2"
    assert sorted(man["keys"]) == ["under_score/x__y", "w/gate",
                                   "w__gate"]
    restored, _ = ck.restore(jax.eval_shape(lambda t: t, tree))
    assert float(restored["w__gate"][0]) == 1.0
    assert float(restored["w"]["gate"][0]) == 2.0
    assert float(restored["under_score"]["x__y"][0]) == 3.0


def test_legacy_checkpoint_readable(tmp_path, tree):
    # A pre-v2 checkpoint (v1 mangling, no "key_escape" manifest field)
    # must still restore via the legacy decode path.
    d = Path(tmp_path) / "step_0000000003"
    d.mkdir()
    leaves = {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
              "nested/b": np.ones((2,), np.float32),
              "nested/c": np.asarray(3, np.int32)}
    np.savez(d / "leaves.npz",
             **{k.replace("/", "__"): v for k, v in leaves.items()})
    man = {"step": 3, "time": 0.0, "keys": sorted(leaves),
           "dtypes": {k: str(v.dtype) for k, v in leaves.items()},
           "extra": {}}          # no "key_escape": legacy manifest
    (d / "manifest.json").write_text(json.dumps(man))
    (d / "COMMITTED").write_text("ok")
    proto = {"a": jax.ShapeDtypeStruct((3, 4), jnp.float32),
             "nested": {"b": jax.ShapeDtypeStruct((2,), jnp.float32),
                        "c": jax.ShapeDtypeStruct((), jnp.int32)}}
    restored, step = Checkpointer(tmp_path, async_save=False).restore(proto)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]), leaves["a"])
    assert int(restored["nested"]["c"]) == 3


def test_slow_async_writer_not_dropped(tmp_path, tree, monkeypatch):
    # Regression for the async-save lifecycle: a writer still flushing
    # must (a) run on a non-daemon thread (interpreter shutdown joins it
    # instead of killing it mid-write), (b) not race all_steps()/
    # restore() on the main thread, and (c) be fully visible after
    # wait().
    import time as _time

    import repro.checkpoint.checkpointer as ckpt_mod
    real_savez = ckpt_mod.np.savez

    def slow_savez(*a, **kw):
        _time.sleep(0.3)
        return real_savez(*a, **kw)

    monkeypatch.setattr(ckpt_mod.np, "savez", slow_savez)
    ck = Checkpointer(tmp_path, async_save=True)
    ck.save(1, tree)
    ck.wait()
    ck.save(2, tree)
    assert ck._thread is not None and not ck._thread.daemon
    # Concurrent listing/restore while step 2 is mid-write: sees only
    # committed state, never a half-written directory.
    proto = jax.eval_shape(lambda t: t, tree)
    for _ in range(5):
        steps = ck.all_steps()
        assert steps in ([1], [1, 2])
        _, got = ck.restore(proto)
        assert got in (1, 2)
    ck.wait()
    assert ck.all_steps() == [1, 2]
    _, got = ck.restore(proto)
    assert got == 2
