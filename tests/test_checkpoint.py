"""Checkpointer: roundtrip, atomic commit, GC, elastic restore."""
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer


@pytest.fixture()
def tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "nested": {"b": jnp.ones((2,), jnp.bfloat16),
                       "c": jnp.asarray(3, jnp.int32)}}


def test_roundtrip(tmp_path, tree):
    ck = Checkpointer(tmp_path, async_save=False)
    ck.save(5, tree)
    proto = jax.eval_shape(lambda t: t, tree)
    restored, step = ck.restore(proto)
    assert step == 5
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_async_save(tmp_path, tree):
    ck = Checkpointer(tmp_path, async_save=True)
    ck.save(1, tree)
    ck.wait()
    assert ck.latest_step() == 1


def test_uncommitted_checkpoint_ignored(tmp_path, tree):
    ck = Checkpointer(tmp_path, async_save=False)
    ck.save(1, tree)
    # simulate a crash mid-save at step 2: directory without COMMITTED
    broken = Path(tmp_path) / "step_0000000002"
    broken.mkdir()
    (broken / "leaves.npz").write_bytes(b"garbage")
    assert ck.latest_step() == 1
    restored, step = ck.restore(jax.eval_shape(lambda t: t, tree))
    assert step == 1


def test_keep_last_k(tmp_path, tree):
    ck = Checkpointer(tmp_path, keep_last_k=2, async_save=False)
    for s in [1, 2, 3, 4]:
        ck.save(s, tree)
    assert ck.all_steps() == [3, 4]


def test_shape_mismatch_raises(tmp_path, tree):
    ck = Checkpointer(tmp_path, async_save=False)
    ck.save(1, tree)
    bad = dict(tree)
    bad["a"] = jnp.zeros((5, 5))
    with pytest.raises(ValueError, match="shape"):
        ck.restore(jax.eval_shape(lambda t: t, bad))


def test_missing_leaf_raises(tmp_path, tree):
    ck = Checkpointer(tmp_path, async_save=False)
    ck.save(1, tree)
    bigger = dict(tree)
    bigger["extra"] = jnp.zeros((2,))
    with pytest.raises(KeyError):
        ck.restore(jax.eval_shape(lambda t: t, bigger))


def test_manifest(tmp_path, tree):
    ck = Checkpointer(tmp_path, async_save=False)
    ck.save(7, tree, extra={"loss": 1.5})
    m = ck.manifest()
    assert m["step"] == 7 and m["extra"]["loss"] == 1.5
    assert "a" in m["keys"]
