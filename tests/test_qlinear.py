"""qeinsum: adjoint derivation, gradient flow, FP8 error bounds, remat."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.precision_policy import (AMAX_FP8, BASELINE, PAPER_FP8,
                                         PAPER_FP8_RNE)
from repro.core.qlinear import adjoint_specs, parse_spec, qeinsum, qmatmul


class TestAdjointSpecs:
    @pytest.mark.parametrize("spec,da,db", [
        ("mk,kn->mn", "mn,kn->mk", "mk,mn->kn"),
        ("bsk,kn->bsn", "bsn,kn->bsk", "bsk,bsn->kn"),
        ("bhqd,bhkd->bhqk", "bhqk,bhkd->bhqd", "bhqd,bhqk->bhkd"),
        ("ecd,edf->ecf", "ecf,edf->ecd", "ecd,ecf->edf"),
    ])
    def test_derivation(self, spec, da, db):
        assert adjoint_specs(spec) == (da, db)

    def test_rejects_sum_only_index(self):
        with pytest.raises(ValueError):
            adjoint_specs("ab,cd->ad")  # b summed-only in lhs

    def test_rejects_ellipsis(self):
        with pytest.raises(ValueError):
            parse_spec("...k,kn->...n")

    @pytest.mark.parametrize("spec,ash,bsh", [
        ("mk,kn->mn", (8, 16), (16, 4)),
        ("bsk,kn->bsn", (2, 8, 16), (16, 4)),
        ("bhqd,bhkd->bhqk", (2, 3, 8, 16), (2, 3, 8, 16)),
        ("ecd,edf->ecf", (4, 8, 16), (4, 16, 8)),
    ])
    def test_adjoints_match_autodiff(self, spec, ash, bsh):
        """Baseline-mode qeinsum gradients == plain einsum gradients."""
        a = jax.random.normal(jax.random.PRNGKey(0), ash)
        b = jax.random.normal(jax.random.PRNGKey(1), bsh) * 0.3

        def f_q(a, b):
            return (qeinsum(spec, a, b, cfg=BASELINE)
                    .astype(jnp.float32) ** 2).sum()

        def f_p(a, b):
            y = jnp.einsum(spec, a.astype(jnp.bfloat16),
                           b.astype(jnp.bfloat16),
                           preferred_element_type=jnp.float32)
            return (y.astype(jnp.bfloat16).astype(jnp.float32) ** 2).sum()

        gq = jax.grad(f_q, argnums=(0, 1))(a, b)
        gp = jax.grad(f_p, argnums=(0, 1))(a, b)
        for x, y in zip(gq, gp):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=2e-2, atol=1e-3)


class TestFP8Path:
    def test_forward_error_bounded(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 128))
        w = jax.random.normal(jax.random.PRNGKey(1), (128, 32)) * 0.1
        y8 = qmatmul(x, w, key=jax.random.PRNGKey(2), cfg=PAPER_FP8)
        yb = qmatmul(x, w, cfg=BASELINE)
        rel = (np.linalg.norm(np.asarray(y8 - yb, np.float32))
               / np.linalg.norm(np.asarray(yb, np.float32)))
        assert rel < 0.2, rel   # e5m2 eps=0.25; GEMM averages it down

    def test_amax_scaling_tightens_error(self):
        # 2e-5 puts most magnitudes in e5m2's subnormal regime where plain
        # quantization is coarse; amax scaling recovers the full mantissa.
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 128)) * 2e-5
        w = jax.random.normal(jax.random.PRNGKey(1), (128, 32)) * 2e-5
        yb = np.asarray(qmatmul(x, w, cfg=BASELINE), np.float32)
        y_plain = np.asarray(qmatmul(x, w, key=jax.random.PRNGKey(2),
                                     cfg=PAPER_FP8), np.float32)
        y_amax = np.asarray(qmatmul(x, w, key=jax.random.PRNGKey(2),
                                    cfg=AMAX_FP8), np.float32)
        err_plain = np.linalg.norm(y_plain - yb)
        err_amax = np.linalg.norm(y_amax - yb)
        assert err_amax < err_plain  # tiny values underflow without scaling

    def test_grads_finite_and_nonzero(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 32))
        w = jax.random.normal(jax.random.PRNGKey(1), (32, 16)) * 0.1

        def loss(x, w, k):
            return (qmatmul(x, w, key=k, cfg=PAPER_FP8)
                    .astype(jnp.float32) ** 2).mean()

        gx, gw = jax.jit(jax.grad(loss, argnums=(0, 1)))(
            x, w, jax.random.PRNGKey(3))
        assert bool(jnp.isfinite(gx).all() and jnp.isfinite(gw).all())
        assert float(jnp.abs(gw).sum()) > 0

    def test_error_overflow_propagates_to_grads(self):
        """With saturate_bwd=False, a huge cotangent must produce non-finite
        weight grads (the dynamic loss scaler's back-off signal)."""
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 32))
        w = jax.random.normal(jax.random.PRNGKey(1), (32, 8))

        def loss(w):
            y = qmatmul(x, w, key=jax.random.PRNGKey(2), cfg=PAPER_FP8)
            return (y.astype(jnp.float32) * 1e9).sum()  # enormous dy

        g = jax.grad(loss)(w)
        assert not bool(jnp.isfinite(g).all())

    def test_rne_config_needs_no_key(self):
        x = jnp.ones((4, 8))
        w = jnp.ones((8, 4))
        y = qmatmul(x, w, cfg=PAPER_FP8_RNE)
        assert y.shape == (4, 4)

    def test_sr_config_requires_key(self):
        with pytest.raises(ValueError, match="needs a PRNG key"):
            qmatmul(jnp.ones((4, 8)), jnp.ones((8, 4)), cfg=PAPER_FP8)

    def test_remat_consistency(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 32))
        w = jax.random.normal(jax.random.PRNGKey(1), (32, 16)) * 0.1

        def loss(w, k):
            return (qmatmul(x, w, key=k, cfg=PAPER_FP8)
                    .astype(jnp.float32) ** 2).mean()

        g1 = jax.jit(jax.grad(loss))(w, jax.random.PRNGKey(2))
        g2 = jax.jit(jax.grad(jax.remat(loss)))(w, jax.random.PRNGKey(2))
        np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))

    def test_pallas_interpret_backend_matches_xla(self):
        import dataclasses
        x = jax.random.normal(jax.random.PRNGKey(0), (16, 128))
        w = jax.random.normal(jax.random.PRNGKey(1), (128, 128)) * 0.1
        cfg_x = dataclasses.replace(PAPER_FP8_RNE, backend="xla",
                                    output_dtype="float32")
        cfg_p = dataclasses.replace(PAPER_FP8_RNE,
                                    backend="pallas_interpret",
                                    output_dtype="float32")
        yx = qmatmul(x, w, cfg=cfg_x)
        yp = qmatmul(x, w, cfg=cfg_p)
        np.testing.assert_allclose(np.asarray(yx), np.asarray(yp),
                                   rtol=1e-5, atol=1e-5)
