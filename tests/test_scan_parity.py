"""Scanned vs unrolled stack parity for per-layer delayed-scaling sites.

With per-layer sites, a scanned stack (cfg.scan_layers=True) must be
equivalent to the unrolled stack (False) site-for-site:

 * the registries are in bijection — scanned site "…/stack_p/…" row g maps
   to unrolled site "…/layer_{g*P+p}/…" — with identical total row counts,
 * the per-layer scale trajectories match: observations are amaxes of
   fp8-quantized payloads, so XLA's scan-vs-unrolled lowering noise (the
   UNQUANTIZED baseline already differs — bf16 fusions reassociate, the
   scan transpose reorders the backward) almost always quantizes away.
   Forward (W/A) rows are overwhelmingly bit-equal with a one-notch
   envelope; backward (E/G) rows, riding the reassociated cotangents, get
   a factor-2 envelope with a majority exactly equal,
 * losses match within the same lowering noise,
 * the enlarged (multi-row) ScaleState round-trips through Checkpointer.
"""
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.precision_policy import PrecisionPolicy, QuantConfig
from repro.models.config import ModelConfig
from repro.models.transformer import init_lm, lm_loss
from repro.scaling import DelayedScaling, discover_lm_sites
from repro.scaling.state import ScalingConfig, SiteRegistry
from repro.train.step import make_optimizer_for, make_train_step

N_LAYERS = 4
B, S = 2, 16
VOCAB = 64

RNE_DELAYED = QuantConfig(scaling="delayed", act_rounding="rne",
                          error_rounding="rne", grad_rounding="rne",
                          saturate_bwd=True)


def _cfg(scan: bool, quant: QuantConfig = RNE_DELAYED) -> ModelConfig:
    return ModelConfig(arch="parity", n_layers=N_LAYERS, d_model=32,
                       n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=VOCAB,
                       max_seq_len=32, policy=PrecisionPolicy(quant=quant),
                       remat=False, scan_layers=scan)


def _stack_params(params_unrolled, cfg_scan: ModelConfig):
    """Restack unrolled per-layer decoder params into the scanned layout
    (stack position p, group g <- layer g*P+p), so both lowerings run the
    SAME weights."""
    P = len(cfg_scan.pattern())
    G = N_LAYERS // P
    dec = params_unrolled["decoder"]
    stacked = {
        f"stack_{p}": jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs),
            *[dec[f"layer_{g * P + p}"] for g in range(G)])
        for p in range(P)}
    out = dict(params_unrolled)
    out["decoder"] = stacked
    return out, P, G


def _key_pairs(reg_s: SiteRegistry, reg_u: SiteRegistry, P: int, G: int):
    """[(scanned key, row offset | None, unrolled key)] covering every row."""
    pairs = []
    for k in reg_s.keys:
        m = re.match(r"(.*?)stack_(\d+)/(.*)$", k)
        if m and reg_s.n_rows[k] == G:
            pre, p, rest = m.group(1), int(m.group(2)), m.group(3)
            for g in range(G):
                pairs.append((k, g, f"{pre}layer_{g * P + p}/{rest}"))
        else:
            pairs.append((k, None, k))
    return pairs


def _setup(quant: QuantConfig = RNE_DELAYED):
    cfg_u, cfg_s = _cfg(False, quant), _cfg(True, quant)
    pu = init_lm(jax.random.PRNGKey(0), cfg_u)
    ps, P, G = _stack_params(pu, cfg_s)
    proto = {"tokens": jnp.zeros((B, S), jnp.int32),
             "labels": jnp.zeros((B, S), jnp.int32)}
    reg_u = discover_lm_sites(cfg_u, pu, proto)
    reg_s = discover_lm_sites(cfg_s, ps, proto)
    return cfg_u, cfg_s, pu, ps, reg_u, reg_s, P, G


class TestRegistryBijection:
    def test_row_bijection_and_counts(self):
        _, _, _, _, reg_u, reg_s, P, G = _setup()
        assert G > 1   # the stack really scans
        pairs = _key_pairs(reg_s, reg_u, P, G)
        # every scanned row maps onto a distinct unrolled key, covering it
        unrolled_targets = [uk for _, _, uk in pairs]
        assert sorted(unrolled_targets) == sorted(reg_u.keys)
        assert len(reg_s) == len(reg_u)          # same total rows
        # every per-layer site owns exactly n_groups rows
        stacked = {k: n for k, n in reg_s.n_rows.items() if n > 1}
        assert stacked
        assert all(n == G for n in stacked.values())
        # token sites carry the same multiplicity
        assert all(reg_s.token_site_layers[s] == G
                   for s in reg_s.token_sites
                   if "stack_" in s)

    def test_scanned_state_is_enlarged(self):
        _, _, _, _, reg_u, reg_s, _, G = _setup()
        ds = DelayedScaling(reg_s)
        st = ds.init()
        assert st.scale.shape == (len(reg_s),)
        assert len(reg_s) > len(reg_s.keys)   # rows > keys: per-layer spans


class TestLossAndTrajectoryParity:
    def _run(self, steps=5, update_weights=False):
        cfg_u, cfg_s, pu, ps, reg_u, reg_s, P, G = _setup()
        ds_u = DelayedScaling(reg_u, ScalingConfig(), qcfg=RNE_DELAYED)
        ds_s = DelayedScaling(reg_s, ScalingConfig(), qcfg=RNE_DELAYED)
        opt_u = make_optimizer_for(cfg_u, learning_rate=1e-3)
        opt_s = make_optimizer_for(cfg_s, learning_rate=1e-3)
        step_u = jax.jit(make_train_step(cfg_u, opt_u, scaling=ds_u))
        step_s = jax.jit(make_train_step(cfg_s, opt_s, scaling=ds_s))
        st_u0, st_s0 = opt_u.init(pu), opt_s.init(ps)
        st_u, st_s = st_u0, st_s0
        ss_u, ss_s = ds_u.init(), ds_s.init()
        pairs = _key_pairs(reg_s, reg_u, P, G)
        rng = np.random.default_rng(0)
        traj = []
        for i in range(steps):
            toks = jnp.asarray(rng.integers(0, VOCAB, (B, S)), jnp.int32)
            batch = {"tokens": toks, "labels": toks}
            (st_u, ss_u), mu = step_u(st_u, ss_u, batch,
                                      jax.random.PRNGKey(i))
            (st_s, ss_s), ms = step_s(st_s, ss_s, batch,
                                      jax.random.PRNGKey(i))
            if not update_weights:   # isolate scale dynamics from weight
                st_u, st_s = st_u0, st_s0   # drift between the lowerings
            sc_u, sc_s = np.asarray(ss_u.scale), np.asarray(ss_s.scale)
            vu = np.asarray([sc_u[reg_u.index[uk]] for _, _, uk in pairs])
            vs = np.asarray([sc_s[reg_s.index[k] + (g or 0)]
                             for k, g, _ in pairs])
            cls = np.asarray([reg_s.class_letter(k) for k, _, _ in pairs])
            traj.append((float(mu["loss"]), float(ms["loss"]),
                         vu, vs, cls))
        return traj

    def test_losses_match_within_lowering_noise(self):
        for lu, ls, *_ in self._run(steps=4, update_weights=True):
            np.testing.assert_allclose(lu, ls, rtol=2e-2)

    def test_per_layer_wa_scale_trajectories_identical(self):
        """Forward observations come from quantized fp8 payloads: the
        lowering noise almost always rounds away, so per-layer W/A rows are
        overwhelmingly bit-equal step for step, never off by more than one
        e5m2 mantissa notch (adjacent grid ratio <= 1.25)."""
        for _, _, vu, vs, cls in self._run(steps=5):
            fwd = np.isin(cls, ["W", "A"])
            assert (vu[fwd] == vs[fwd]).mean() >= 0.85, \
                (vu[fwd], vs[fwd])
            ratio = vs[fwd] / np.maximum(vu[fwd], 1e-30)
            assert (ratio <= 1.25).all() and (ratio >= 0.8).all(), ratio

    def test_per_layer_eg_scale_trajectories_match(self):
        """Backward observations ride the scan-transposed cotangents, where
        the two lowerings reassociate: amaxes may land one fp8 notch apart,
        and a notch at the saturation boundary can fire the growth probe on
        one side only (one extra 2x). Envelope: within 4x everywhere,
        majority of rows exactly equal, median ratio 1."""
        fracs = []
        for _, _, vu, vs, cls in self._run(steps=5):
            bwd = np.isin(cls, ["E", "G"])
            ratio = vs[bwd] / np.maximum(vu[bwd], 1e-30)
            assert (ratio <= 4.0).all() and (ratio >= 0.25).all(), ratio
            assert np.median(ratio) == 1.0
            fracs.append((vu[bwd] == vs[bwd]).mean())
        # notch flips accumulate through history; exactness decays but the
        # bulk of rows stays bit-equal across the trajectory
        assert np.mean(fracs) > 0.5 and min(fracs) > 0.3, fracs

    def test_per_layer_scales_differ_across_layers(self):
        """The point of per-layer sites: rows within one scanned site track
        THEIR layer, not a shared per-stack-position statistic — and agree
        with the unrolled per-layer sites doing the same."""
        *_, (_, _, vu, vs, cls) = self._run(steps=5)
        fwd = np.isin(cls, ["W", "A"])
        # the unrolled reference itself has layer-distinct scales...
        assert len(np.unique(vu[fwd])) > len(vu[fwd]) // 4
        # ...and the scanned per-layer rows track them
        np.testing.assert_allclose(vs[fwd], vu[fwd], rtol=0.25)
        assert len(np.unique(vs[fwd])) > len(vs[fwd]) // 4


class TestMicrobatchedPerLayerObservations:
    def test_microbatch_reduction_keeps_layer_axis(self):
        """Gradient accumulation stacks metrics over the microbatch axis;
        the amax reduction must collapse ONLY that axis — per-layer
        (n_groups,) observation vectors of scanned sites survive, so each
        layer's history row stays its own (regression: a full .max() used
        to broadcast one group-wide envelope over every row)."""
        cfg = _cfg(True)
        params = init_lm(jax.random.PRNGKey(0), cfg)
        proto = {"tokens": jnp.zeros((4, S), jnp.int32),
                 "labels": jnp.zeros((4, S), jnp.int32)}
        reg = discover_lm_sites(cfg, params, proto)
        ds = DelayedScaling(reg, qcfg=RNE_DELAYED)
        opt = make_optimizer_for(cfg, learning_rate=1e-3)
        step = jax.jit(make_train_step(cfg, opt, n_microbatches=2,
                                       scaling=ds))
        state, sstate = opt.init(params), ds.init()
        rng = np.random.default_rng(0)
        for i in range(2):
            toks = jnp.asarray(rng.integers(0, VOCAB, (4, S)), jnp.int32)
            (state, sstate), _ = step(state, sstate,
                                      {"tokens": toks, "labels": toks},
                                      jax.random.PRNGKey(i))
        hist = np.asarray(sstate.amax_history)
        # per-layer rows must record per-layer amaxes (activations/errors
        # differ with depth), not one broadcast group envelope — with the
        # bug EVERY stacked site's rows were identical
        stacked = [k for k in reg.keys if reg.n_rows[k] > 1]
        assert stacked
        distinct = 0
        for k in stacked:
            i, n = reg.index[k], reg.n_rows[k]
            if len(np.unique(hist[i:i + n, 0])) > 1:
                distinct += 1
        assert distinct > len(stacked) // 2, \
            {k: hist[reg.index[k]:reg.index[k] + reg.n_rows[k], 0]
             for k in stacked}


class TestEnlargedScaleStateCheckpoint:
    def test_round_trip_through_checkpointer(self, tmp_path):
        from repro.checkpoint import Checkpointer
        _, _, _, _, _, reg_s, _, G = _setup()
        ds = DelayedScaling(reg_s, ScalingConfig(history_len=4))
        st = ds.init()
        # feed per-layer vector observations so the multi-row structure is
        # actually populated
        rng = np.random.default_rng(3)
        obs = {}
        for k in reg_s.keys:
            n = reg_s.n_rows[k]
            v = rng.uniform(0.5, 4.0, (n,)).astype(np.float32)
            obs[k] = jnp.asarray(v if n > 1 else v[0])
        st = ds.update(st, obs)
        ck = Checkpointer(tmp_path, async_save=False)
        ck.save(11, {"scales": st},
                extra={"rows": {k: reg_s.n_rows[k] for k in reg_s.keys}})
        proto = jax.eval_shape(lambda s: s, {"scales": ds.init()})
        restored, step = ck.restore(proto)
        assert step == 11
        np.testing.assert_array_equal(
            np.asarray(st.amax_history),
            np.asarray(restored["scales"].amax_history))
        np.testing.assert_array_equal(
            np.asarray(st.scale), np.asarray(restored["scales"].scale))
        assert ck.manifest(11)["extra"]["rows"][reg_s.keys[0]] \
            == reg_s.n_rows[reg_s.keys[0]]

    def test_update_accepts_vector_and_scalar_observations(self):
        reg = SiteRegistry(["s#a.A", "t#E"], site_layers={"s#a.A": 3})
        ds = DelayedScaling(reg, ScalingConfig(history_len=2, margin=1.0))
        st = ds.update(ds.init(), {"s#a.A": jnp.asarray([1.0, 2.0, 4.0]),
                                   "t#E": jnp.float32(8.0)})
        np.testing.assert_array_equal(np.asarray(st.amax_history[:, 0]),
                                      [1.0, 2.0, 4.0, 8.0])
        sc = np.asarray(st.scale)
        np.testing.assert_allclose(sc[:3], np.asarray([1.0, 2.0, 4.0])
                                   / 57344.0)
        # scalar observation of a stacked site broadcasts over its rows
        st2 = ds.update(st, {"s#a.A": jnp.float32(16.0)})
        np.testing.assert_array_equal(np.asarray(st2.amax_history[:3, 0]),
                                      [16.0, 16.0, 16.0])


class TestPerLayerFrozenServing:
    """ROADMAP follow-up: frozen serving scales for scanned stacks no longer
    collapse to the max envelope — freeze(per_layer=True) keeps one scale
    per layer, threaded through the serve-time scan xs exactly like the
    collect-mode scale vectors."""

    def _calibrated(self):
        from repro.scaling.calibrate import calibrate, freeze
        pol = PrecisionPolicy(quant=RNE_DELAYED, kv_cache_format="e5m2")
        cfg_s = _cfg(True).replace(policy=pol)
        cfg_u = _cfg(False).replace(policy=pol)
        pu = init_lm(jax.random.PRNGKey(0), cfg_u)
        ps, P, G = _stack_params(pu, cfg_s)
        rng = np.random.default_rng(1)
        batches = [{"tokens": jnp.asarray(rng.integers(0, VOCAB, (B, 12)),
                                          jnp.int32)} for _ in range(3)]
        ds_s, st_s = calibrate(ps, cfg_s, batches,
                               scaling_cfg=ScalingConfig(margin=1.0))
        frozen_s = freeze(ds_s, st_s, per_layer=True)
        return cfg_s, cfg_u, ps, pu, frozen_s, P, G

    def test_per_layer_freeze_emits_vectors_and_round_trips_json(
            self, tmp_path):
        from repro.scaling.calibrate import (load_frozen, save_frozen)
        cfg_s, _, _, _, frozen_s, P, G = self._calibrated()
        vec = {k: v for k, v in frozen_s.items() if isinstance(v, list)}
        assert vec                       # scanned sites keep per-layer rows
        assert all(len(v) == G for v in vec.values())
        # distinct layers calibrate to distinct scales (the envelope threw
        # this fidelity away)
        assert any(len(set(v)) > 1 for v in vec.values())
        save_frozen(tmp_path, frozen_s)
        assert load_frozen(tmp_path) == frozen_s

    def test_freeze_with_formats_passes_per_layer_through(self):
        """The format-checked serving flow exposes the same per-layer knob
        (a site's format is shared by all of its layer rows)."""
        from repro.scaling.calibrate import freeze_with_formats
        from repro.scaling.state import DelayedScaling
        reg = SiteRegistry(["dec/stack_0/mlp/up#a.A", "dec/head#b.W"],
                           site_layers={"dec/stack_0/mlp/up#a.A": 3})
        ds = DelayedScaling(reg, ScalingConfig(history_len=2, margin=1.0))
        st = ds.update(ds.init(),
                       {"dec/stack_0/mlp/up#a.A": jnp.asarray([1., 2., 4.]),
                        "dec/head#b.W": jnp.float32(8.0)})
        scales, formats = freeze_with_formats(ds, st, per_layer=True)
        np.testing.assert_allclose(
            scales["dec/stack_0/mlp/up#a.A"],
            [x / 57344.0 for x in (1.0, 2.0, 4.0)], rtol=1e-6)
        assert isinstance(scales["dec/head#b.W"], float)
        assert formats["dec/stack_0/mlp/up#a.A"] == "e5m2"

    def test_uniform_vectors_bitmatch_scalar_constants(self):
        """Threading correctness, bitwise: serving a scanned stack with
        per-layer vectors that are CONSTANT across layers must bit-match
        serving with the legacy scalar constants — the per-layer slices
        ride the scan xs but carry identical values, so any bit difference
        means the threaded path computes something other than the constant
        path (same lowering on both sides, so this is exact)."""
        from repro.models.transformer import init_stack_state
        from repro.train.step import make_serve_prefill
        cfg_s, _, ps, _, frozen_s, P, G = self._calibrated()
        env = {k: (max(v) if isinstance(v, list) else v)
               for k, v in frozen_s.items()}
        uniform = {k: ([env[k]] * G if isinstance(v, list) else v)
                   for k, v in frozen_s.items()}
        states = init_stack_state(cfg_s, B, max_len=24, n_layers=N_LAYERS)
        toks = jnp.asarray(np.random.default_rng(2).integers(
            0, VOCAB, (B, 8)), jnp.int32)
        lv, _ = jax.jit(make_serve_prefill(cfg_s, uniform))(
            ps, {"tokens": toks}, states)
        lc, _ = jax.jit(make_serve_prefill(cfg_s, env))(
            ps, {"tokens": toks}, states)
        np.testing.assert_array_equal(np.asarray(lv, np.float32),
                                      np.asarray(lc, np.float32))

    def test_per_layer_freeze_matches_unrolled_reference(self):
        """Per-layer fidelity: the frozen per-layer scale of scanned site
        "…stack_p/…" row g bit-matches (within the one-notch forward
        envelope bounded at the top of this file) the frozen scale the
        UNROLLED reference calibrates for "…layer_{g*P+p}/…" — the envelope
        freeze threw exactly this per-layer structure away. (Logit-level
        comparison across the two lowerings is NOT asserted: a single fp8
        rounding flip of lowering noise amplifies through the stack.)"""
        from repro.scaling.calibrate import calibrate, freeze
        cfg_s, cfg_u, ps, pu, frozen_s, P, G = self._calibrated()
        rng = np.random.default_rng(1)   # same batches as _calibrated
        batches = [{"tokens": jnp.asarray(rng.integers(0, VOCAB, (B, 12)),
                                          jnp.int32)} for _ in range(3)]
        ds_u, st_u = calibrate(pu, cfg_u, batches,
                               scaling_cfg=ScalingConfig(margin=1.0))
        frozen_u = freeze(ds_u, st_u, per_layer=True)
        assert not any(isinstance(v, list) for v in frozen_u.values())
        pairs = []
        for k, v in frozen_s.items():
            m = re.match(r"(.*?)stack_(\d+)/(.*)$", k)
            if m and isinstance(v, list):
                for g, val in enumerate(v):
                    uk = f"{m.group(1)}layer_{g * P + int(m.group(2))}" \
                        f"/{m.group(3)}"
                    pairs.append((val, frozen_u[uk]))
        assert pairs
        vs = np.asarray([p[0] for p in pairs])
        vu = np.asarray([p[1] for p in pairs])
        assert (vs == vu).mean() >= 0.85, (vs, vu)
        ratio = vs / np.maximum(vu, 1e-30)
        assert (ratio <= 1.25).all() and (ratio >= 0.8).all(), ratio

    def test_per_layer_serving_differs_from_envelope(self):
        """The threaded per-layer constants are live: serving with them
        differs from envelope serving whenever the layers calibrated to
        different scales."""
        from repro.models.transformer import init_stack_state
        from repro.train.step import make_serve_prefill
        cfg_s, _, ps, _, frozen_s, P, G = self._calibrated()
        assert any(isinstance(v, list) and len(set(v)) > 1
                   for v in frozen_s.values())
        env = {k: (max(v) if isinstance(v, list) else v)
               for k, v in frozen_s.items()}
        states = init_stack_state(cfg_s, B, max_len=24, n_layers=N_LAYERS)
        toks = jnp.asarray(np.random.default_rng(2).integers(
            0, VOCAB, (B, 8)), jnp.int32)
        ls, _ = jax.jit(make_serve_prefill(cfg_s, frozen_s))(
            ps, {"tokens": toks}, states)
        le, _ = jax.jit(make_serve_prefill(cfg_s, env))(
            ps, {"tokens": toks}, states)
        assert not (np.asarray(le, np.float32)
                    == np.asarray(ls, np.float32)).all()
