"""Optional-hypothesis shim shared by the property-based test modules.

`from hyputil import given, settings, st`: with hypothesis installed these
are the real decorators/strategies; without it, @given marks the test
skipped and `st` accepts any strategy expression at decoration time so
collection still succeeds.
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    def given(*_a, **_k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed")(f)

    def settings(*_a, **_k):
        return lambda f: f

    class _StrategyStub:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()
