"""Loss scaling: constant / dynamic / enhanced (paper §3.1) + invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyputil import given, settings, st

from repro.core.loss_scale import (LossScaler, all_finite, convnet_scaler,
                                   gnmt_scaler, underflow_fraction)


class TestConstant:
    def test_never_changes(self):
        s = convnet_scaler(10_000.0)
        st_ = s.init()
        for finite in [True, False, True]:
            st_ = s.update(st_, jnp.asarray(finite))
        assert float(st_.scale) == 10_000.0
        assert int(st_.overflow_count) == 1


class TestDynamic:
    def test_backoff_on_overflow(self):
        s = LossScaler(mode="dynamic", init_scale=4096.0)
        st_ = s.update(s.init(), jnp.asarray(False))
        assert float(st_.scale) == 2048.0

    def test_growth_after_interval(self):
        s = LossScaler(mode="dynamic", init_scale=1024.0, growth_interval=3)
        st_ = s.init()
        for _ in range(3):
            st_ = s.update(st_, jnp.asarray(True))
        assert float(st_.scale) == 2048.0

    def test_max_scale_cap(self):
        s = LossScaler(mode="dynamic", init_scale=2.0**23, growth_interval=1,
                       max_scale=2.0**24)
        st_ = s.init()
        for _ in range(5):
            st_ = s.update(st_, jnp.asarray(True))
        assert float(st_.scale) == 2.0**24


class TestEnhanced:
    """Paper Fig. 2b: minimum threshold grows on a schedule."""

    def test_floor_inactive_before_knot(self):
        s = gnmt_scaler()
        st_ = s.init()
        for _ in range(4):   # 8192 -> 512
            st_ = s.update(st_, jnp.asarray(False))
        assert float(st_.scale) == 512.0

    def test_floor_active_after_knot(self):
        s = gnmt_scaler()
        st_ = dataclasses.replace(s.init(), step=jnp.asarray(50_000))
        for _ in range(4):
            st_ = s.update(st_, jnp.asarray(False))
        assert float(st_.scale) == 8192.0   # clamped at the 40K-knot floor

    def test_second_knot(self):
        s = gnmt_scaler()
        st_ = dataclasses.replace(s.init(), step=jnp.asarray(200_000))
        st_ = s.update(st_, jnp.asarray(False))
        assert float(st_.scale) >= 32768.0

    @pytest.mark.parametrize("knot_step,knot_min", [(40_000, 8192.0),
                                                    (150_000, 32768.0)])
    def test_floor_engages_exactly_at_knot(self, knot_step, knot_min):
        """The update that PRODUCES step == knot_step must already clamp to
        the knot's floor (the floor is evaluated at the post-increment
        step; evaluating it pre-increment engages every knot one update
        late)."""
        s = gnmt_scaler()
        # Overflow on the update landing exactly on the knot: back-off wants
        # scale/2, the knot floor must win.
        st_ = dataclasses.replace(s.init(), step=jnp.asarray(knot_step - 1),
                                  scale=jnp.asarray(knot_min, jnp.float32))
        st_ = s.update(st_, jnp.asarray(False))
        assert int(st_.step) == knot_step
        assert float(st_.scale) == knot_min

    @pytest.mark.parametrize("knot_step,knot_min", [(40_000, 8192.0),
                                                    (150_000, 32768.0)])
    def test_floor_inactive_one_before_knot(self, knot_step, knot_min):
        """One update earlier (producing step == knot_step - 1) the knot is
        not yet in force: back-off may drop below the knot's floor."""
        s = gnmt_scaler()
        st_ = dataclasses.replace(s.init(), step=jnp.asarray(knot_step - 2),
                                  scale=jnp.asarray(knot_min, jnp.float32))
        st_ = s.update(st_, jnp.asarray(False))
        assert int(st_.step) == knot_step - 1
        prev_floor = float(s.min_scale_at(jnp.asarray(knot_step - 1)))
        assert float(st_.scale) == max(knot_min * s.backoff_factor,
                                       prev_floor)

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.booleans(), min_size=1, max_size=60),
           st.integers(min_value=0, max_value=300_000))
    def test_invariants(self, finites, start_step):
        """Scale stays within [scheduled_floor, max_scale] and positive."""
        s = gnmt_scaler()
        st_ = dataclasses.replace(s.init(), step=jnp.asarray(start_step))
        for f in finites:
            st_ = s.update(st_, jnp.asarray(f))
            scale = float(st_.scale)
            assert 0 < scale <= s.max_scale
            # The floor in force is the post-increment step's (= st_.step
            # after the update).
            floor = float(s.min_scale_at(st_.step))
            assert scale >= min(floor, s.init_scale)


class TestHelpers:
    def test_all_finite(self):
        assert bool(all_finite({"a": jnp.ones(3), "b": jnp.zeros(2)}))
        assert not bool(all_finite({"a": jnp.array([1.0, np.inf])}))
        assert not bool(all_finite({"a": jnp.array([np.nan])}))

    def test_all_finite_ignores_ints(self):
        assert bool(all_finite({"a": jnp.array([1, 2], jnp.int32)}))

    def test_underflow_fraction(self):
        g = {"g": jnp.array([1e-9, 1e-3, 0.0, 1e-6], jnp.float32)}
        frac = float(underflow_fraction(g, threshold=1.52587890625e-05))
        assert frac == pytest.approx(2 / 3)

    def test_unscale_is_f32(self):
        s = convnet_scaler(1000.0)
        st_ = s.init()
        out = s.unscale(st_, {"g": jnp.ones(3, jnp.bfloat16) * 1000})
        assert out["g"].dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(out["g"]), 1.0, rtol=1e-3)
