"""Distribution: sharding rules (pure), and multi-device behavior via
subprocesses (so the main test session keeps exactly one CPU device)."""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from hyputil import given, settings, st
from repro.distributed.grad_compress import (compressed_psum_mean,
                                             wire_bytes_model)
from repro.distributed.sharding import _spec_for
from repro.models.registry import build_config
from repro.models.transformer import init_lm


class TestShardingRules:
    @pytest.mark.parametrize("path,shape,expected", [
        ("decoder/stack_0/attn/wq", (4, 128, 256), P(None, None, "model")),
        ("decoder/stack_0/attn/wo", (4, 256, 128), P(None, "model", None)),
        ("decoder/stack_0/mlp/up", (4, 128, 512), P(None, None, "model")),
        ("decoder/stack_0/mlp/down", (4, 512, 128), P(None, "model", None)),
        ("embed/table", (9216, 128), P("model", None)),
        ("embed/head", (128, 9216), P(None, "model")),
        ("decoder/stack_0/moe/router", (128, 16), P()),
        ("decoder/stack_0/moe/w_up", (16, 128, 512), P("model", None, None)),
        ("decoder/stack_0/norm1/scale", (128,), P()),
        ("decoder/stack_0/attn/bq", (256,), P("model",)),
    ])
    def test_rules(self, path, shape, expected):
        assert _spec_for(path, shape, model_size=16) == expected

    def test_indivisible_replicates(self):
        # 12 heads x 1536 not divisible by 16 columns? 1536 is divisible;
        # use a genuinely indivisible dim:
        assert _spec_for("decoder/stack_0/attn/wq", (4, 100, 12),
                         model_size=16) == P()

    def test_embed_vocab_fallback_to_d(self):
        # vocab 256206 not divisible by 16 -> shard d instead
        assert _spec_for("embed/table", (256206, 1024), model_size=16) == \
            P(None, "model")


def _run_subprocess(code: str) -> str:
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=560,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root",
             # Force the CPU backend: with libtpu installed but no TPU
             # attached, JAX otherwise burns minutes probing GCP metadata.
             "JAX_PLATFORMS": "cpu",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8"},
        cwd="/root/repo")
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


def _vmap_reduce(grads, error):
    """Drive compressed_psum_mean with vmap's named-axis collectives: same
    psum/pmax/all_to_all/all_gather code path as shard_map, one process,
    no devices needed — `slot i` of the leading axis plays device i."""
    body = lambda tg, te: compressed_psum_mean(tg, te, axis_name="x")
    return jax.vmap(body, axis_name="x")(grads, error)


class TestGradCompress:
    @pytest.mark.parametrize("n,shape", [
        (4, (333,)),      # numel % n != 0 -> padded all_to_all chunks
        (8, (7, 5)),      # 35 % 8 != 0, 2-D leaf
        (4, (1,)),        # degenerate: fewer elements than devices
        (8, (129,)),      # prime-ish odd length
    ])
    def test_padding_indivisible_numel(self, n, shape):
        rng = np.random.default_rng(7)
        g = rng.standard_normal((n,) + shape).astype(np.float32) * 0.01
        red, err = _vmap_reduce({"g": jnp.asarray(g)},
                                {"g": jnp.zeros_like(g)})
        r = np.asarray(red["g"])
        assert r.shape == g.shape and np.asarray(err["g"]).shape == g.shape
        true = g.mean(0)
        rel = np.linalg.norm(r[0] - true) / max(np.linalg.norm(true), 1e-12)
        assert rel < 0.15, rel
        # the reduced mean is replicated: every slot got the same answer
        assert (r == r[0]).all()

    def test_zero_gradients_guard(self):
        # all-zero input: the scale >= 1e-30 clamp must keep 0/scale finite
        z = jnp.zeros((4, 17), jnp.float32)
        red, err = _vmap_reduce({"g": z}, {"g": z})
        assert np.isfinite(np.asarray(red["g"])).all()
        assert float(np.abs(np.asarray(red["g"])).max()) == 0.0
        assert float(np.abs(np.asarray(err["g"])).max()) == 0.0

    def test_error_none_initializes_zeros(self):
        g = jnp.ones((4, 8), jnp.float32)
        body = lambda tg: compressed_psum_mean(tg, None, axis_name="x")
        red, err = jax.vmap(body, axis_name="x")({"g": g})
        assert np.allclose(np.asarray(red["g"]), 1.0, rtol=1e-6)

    def test_residual_is_quantization_error(self):
        # e' = y - dequant(q): one step from zero error leaves a residual
        # bounded by the e5m2 quantization step (~6.25% relative twice over)
        rng = np.random.default_rng(3)
        g = rng.standard_normal((8, 256)).astype(np.float32)
        _, err = _vmap_reduce({"g": jnp.asarray(g)}, {"g": jnp.zeros_like(g)})
        e = np.asarray(err["g"])
        assert float(np.abs(e).max()) <= 0.25 * float(np.abs(g).max())

    @pytest.mark.slow
    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(-6, 6))
    def test_error_feedback_unbiased_over_steps(self, seed, log10_scale):
        """Error feedback makes the compressed mean unbiased over repeated
        steps: with constant per-device grads, the accumulated compressed
        mean tracks T x true mean to within ONE residual, so its relative
        error shrinks vs the single-step quantization error — at any
        gradient magnitude (the shared scale is amax-relative)."""
        rng = np.random.default_rng(seed)
        g = (rng.standard_normal((4, 97)).astype(np.float32)
             * 10.0 ** log10_scale)
        true = g.mean(0)
        if np.linalg.norm(true) < 1e-30:   # pathological draw
            return
        step = jax.jit(_vmap_reduce)
        red, err = step({"g": jnp.asarray(g)}, {"g": jnp.zeros_like(g)})
        rel1 = np.linalg.norm(np.asarray(red["g"])[0] - true) \
            / np.linalg.norm(true)
        acc = np.zeros_like(true)
        T = 16
        err = {"g": jnp.zeros_like(jnp.asarray(g))}
        for _ in range(T):
            red, err = step({"g": jnp.asarray(g)}, err)
            acc = acc + np.asarray(red["g"])[0]
        rel_acc = np.linalg.norm(acc - T * true) / (T * np.linalg.norm(true))
        assert rel_acc < max(rel1, 1e-6) + 1e-7, (rel_acc, rel1)
        assert rel_acc < 0.05, rel_acc


@pytest.mark.slow
def test_grad_compression_correct_and_error_feedback():
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.grad_compress import compressed_psum_mean
        from repro.distributed.sharding import shard_map_compat
        from repro.launch.mesh import enter_mesh, make_mesh
        mesh = make_mesh((8,), ("pod",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 512)) * 0.01
        e0 = jnp.zeros_like(g)
        def step(g, e):
            def inner(gl, el):
                r, ne = compressed_psum_mean({"g": gl[0]}, {"g": el[0]},
                                             axis_name="pod")
                return r["g"][None], ne["g"][None]
            return shard_map_compat(inner, mesh,
                                    (P("pod", None), P("pod", None)),
                                    (P("pod", None), P("pod", None)))(g, e)
        with enter_mesh(mesh):
            red, err = jax.jit(step)(g, e0)
        true = np.asarray(g).mean(0)
        rel = np.linalg.norm(np.asarray(red)[0] - true) / np.linalg.norm(true)
        assert rel < 0.15, rel
        acc_t, acc_c, e = 0, 0, e0
        for _ in range(16):
            red, e = jax.jit(step)(g, e)
            acc_t = acc_t + true; acc_c = acc_c + np.asarray(red)[0]
        rel_acc = np.linalg.norm(acc_c - acc_t) / np.linalg.norm(acc_t)
        assert rel_acc < rel, (rel_acc, rel)   # error feedback improves it
        print("OK", rel, rel_acc)
    """)
    assert "OK" in out


@pytest.mark.slow
def test_small_mesh_dryrun_train_and_decode():
    """Lower+compile a reduced arch on a 2x4 mesh: the full distribution
    path (param/batch/state specs, SP, ZeRO) on 8 host devices."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp
        from repro.launch.mesh import enter_mesh, make_mesh
        from repro.launch.specs import build_cell, SHAPES
        SHAPES["tiny_train"] = dict(seq=64, batch=8, mode="train")
        SHAPES["tiny_decode"] = dict(seq=64, batch=8, mode="decode")
        mesh = make_mesh((2, 4), ("data", "model"))
        import repro.launch.specs as S
        S.SHAPES = SHAPES
        for arch in ["qwen2-1.5b", "dbrx-132b", "recurrentgemma-9b"]:
            for shape in ["tiny_train", "tiny_decode"]:
                import repro.models.registry as R
                cfg = R.build_config(arch, smoke=True)
                orig = R.build_config
                R.build_config = lambda a, smoke=False, **kw: \
                    orig(a, smoke=True, **kw)
                S._cfg_for_cell.cache_clear()
                try:
                    from repro.launch.mesh import jit_shardings
                    with enter_mesh(mesh):
                        cell = build_cell(arch, shape, mesh)
                        c = jax.jit(cell["fn"],
                                    in_shardings=jit_shardings(
                                        mesh, cell["in_shardings"]),
                                    out_shardings=jit_shardings(
                                        mesh, cell["out_shardings"])
                                    ).lower(*cell["args"]).compile()
                        assert c.memory_analysis().temp_size_in_bytes > 0
                        print("OK", arch, shape)
                finally:
                    R.build_config = orig
    """)
    assert out.count("OK") == 6


@pytest.mark.slow
def test_real_sharded_train_step_runs():
    """Actually EXECUTE a sharded train step on 8 devices and check the
    loss is finite and the loss scale updates."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import enter_mesh, make_mesh
        from repro.models.registry import build_config
        from repro.models.transformer import init_lm
        from repro.train.step import make_optimizer_for, make_train_step
        from repro.distributed.sharding import param_specs, batch_specs
        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = build_config("qwen2-1.5b", smoke=True).replace(
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab_size=512, remat=False)
        opt = make_optimizer_for(cfg, learning_rate=1e-3)
        step = make_train_step(cfg, opt)
        with enter_mesh(mesh):
            params = init_lm(jax.random.PRNGKey(0), cfg)
            state = opt.init(params)
            toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 512)
            batch = {"tokens": toks, "labels": toks,
                     "loss_mask": jnp.ones((8, 32), jnp.float32)}
            bspec = batch_specs(batch, mesh)
            batch = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
                batch, bspec)
            state2, m = jax.jit(step)(state, batch, jax.random.PRNGKey(2))
            assert np.isfinite(float(m["loss"]))
            print("OK", float(m["loss"]))
    """)
    assert "OK" in out
