"""Parallelism strategy layer (distributed.strategy): plan composition and
spec derivation as pure tests; wire-format collectives, the convergence law,
and checkpoint round-trips on 8 forced host devices via subprocesses (the
main test session keeps exactly one CPU device)."""
import dataclasses

import jax
import numpy as np
import pytest
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core.precision_policy import DistConfig
from repro.distributed.grad_compress import wire_bytes_model
from repro.distributed.strategy import (DataParallel, ParallelPlan,
                                        TensorParallel, ZeRO1Sharded)
from test_distributed import _run_subprocess  # pytest adds tests/ to path


class TestDistConfig:
    def test_defaults_full_wire(self):
        d = DistConfig()
        assert d.wire == "full" and d.wire_zero_gather == "full"
        assert d.dp and d.zero1 and d.tp and d.wire_axis is None

    def test_bad_wire_rejected(self):
        with pytest.raises(ValueError, match="wire format"):
            DistConfig(wire="fp4")

    def test_bad_zero_gather_rejected(self):
        with pytest.raises(ValueError, match="zero-gather"):
            DistConfig(wire_zero_gather="e5m2")

    def test_replace_roundtrip(self):
        d = dataclasses.replace(DistConfig(), wire="fp8_ef")
        assert d.wire == "fp8_ef"
        assert dataclasses.replace(d, wire="full").wire == "full"


class TestWireBytesModel:
    def test_ring_formula(self):
        tree = {"a": np.zeros((10, 10)), "b": np.zeros((3,))}
        m = wire_bytes_model(tree, 8)
        assert m["numel"] == 103
        hops = 2 * 7 / 8
        assert m["bytes_full_bf16"] == pytest.approx(hops * 103 * 2)
        assert m["bytes_fp8_ef"] == pytest.approx(hops * 103 * 1)
        assert m["ratio_fp8_vs_bf16"] == pytest.approx(0.5)

    def test_single_device_no_wire(self):
        m = wire_bytes_model({"a": np.zeros(4)}, 1)
        assert m["bytes_full_bf16"] == 0.0 and m["ratio_fp8_vs_bf16"] == 0.0

    def test_meets_compression_target(self):
        # the PR's acceptance bar: fp8_ef <= 0.55x the bf16 wire bytes
        m = wire_bytes_model({"g": np.zeros((1024,))}, 4)
        assert m["ratio_fp8_vs_bf16"] <= 0.55


def _mesh1(*names):
    shape = (1,) * len(names)
    return Mesh(np.array(jax.devices()[:1]).reshape(shape), names)


class TestPlanComposition:
    """Plan logic that is independent of device count (size-1 axes)."""

    def test_single_device_plan(self):
        plan = ParallelPlan.build(_mesh1("data"), DistConfig())
        d = plan.describe()
        assert d["dp_axes"] == ["data"] and d["dp_size"] == 1
        assert d["zero1_axis"] is None      # nothing to shard over size-1
        assert d["tp_size"] == 1
        assert not plan.compresses

    def test_fp8_wire_inert_on_one_device(self):
        # the knob is accepted but n_wire == 1 -> no compression path
        plan = ParallelPlan.build(_mesh1("data"), DistConfig(wire="fp8_ef"))
        assert plan.describe()["wire"] == "fp8_ef"
        assert not plan.compresses
        assert plan.wire_bytes({"w": np.zeros(8)})["bytes_per_step"] == 0.0

    def test_strategies_deactivate_via_flags(self):
        plan = ParallelPlan.build(
            _mesh1("pod", "data", "model"),
            DistConfig(dp=False, zero1=False, tp=False))
        assert plan.dp is None and plan.zero1 is None and plan.tp is None
        assert plan.dp_axes == () and plan.wire_axis is None
        with pytest.raises(ValueError, match="nothing to reduce"):
            plan.dp_allreduce()

    def test_wire_axis_prefers_pod(self):
        plan = ParallelPlan.build(_mesh1("pod", "data"), DistConfig())
        assert plan.wire_axis == "pod"
        assert plan.inner_dp_axes == ("data",)

    def test_wire_axis_override_validated(self):
        with pytest.raises(ValueError, match="wire_axis"):
            ParallelPlan.build(_mesh1("data"), DistConfig(wire_axis="pod"))
        plan = ParallelPlan.build(_mesh1("pod", "data"),
                                  DistConfig(wire_axis="data"))
        assert plan.wire_axis == "data"
        assert plan.inner_dp_axes == ("pod",)

    def test_param_specs_replicated_without_tp(self):
        plan = ParallelPlan.build(_mesh1("data"), DistConfig())
        specs = plan.param_specs({"w": np.zeros((4, 4))})
        assert specs["w"] == P()

    def test_wire_state_shapes(self):
        plan = ParallelPlan.build(_mesh1("data"), DistConfig(wire="fp8_ef"))
        err = plan.init_wire_state({"w": np.zeros((3, 5), np.float16)})
        assert np.shape(err["w"]) == (1, 3, 5)
        assert np.asarray(err["w"]).dtype == np.float32
        struct = plan.wire_state_struct({"w": jax.ShapeDtypeStruct(
            (3, 5), np.float16)})
        assert struct["w"].shape == (1, 3, 5)
        assert plan.wire_state_specs(err)["w"] == P("data")

    def test_describe_is_jsonable(self):
        import json
        plan = ParallelPlan.build(_mesh1("pod", "data", "model"),
                                  DistConfig())
        json.dumps(plan.describe())

    def test_strategy_dataclasses(self):
        assert DataParallel().axes == ("pod", "data")
        assert ZeRO1Sharded().axis == "data"
        assert TensorParallel().axis == "model"


# ---- 8-device behavior (subprocesses force the host platform) --------------

def test_wire_collectives_8dev():
    """The satellite bugfix regression: the compressed all-reduce must
    lower through shard_map_compat on this JAX (jax.shard_map does not
    exist on 0.4.37), put real 1-byte f8 payloads in the HLO, and the fp8
    zero-gather + TP-refusal gates must behave."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.precision_policy import DistConfig
        from repro.distributed.strategy import ParallelPlan
        from repro.launch.mesh import make_mesh

        # 1. compressed all-reduce lowers and runs (hierarchical mesh: the
        #    wire axis is 'pod', 'data' stays untouched/replicated).
        mesh = make_mesh((2, 4), ("pod", "data"))
        plan = ParallelPlan.build(mesh, DistConfig(wire="fp8_ef"))
        assert plan.wire_axis == "pod" and plan.n_wire == 2
        g = {"w": jax.random.normal(jax.random.PRNGKey(0), (2, 129)) * 0.01}
        e = {"w": jnp.zeros((2, 129))}
        put = lambda t: jax.tree_util.tree_map(
            lambda x: jax.device_put(x, NamedSharding(mesh, P("pod"))), t)
        fn = jax.jit(plan.dp_allreduce())
        lowered = fn.lower(put(g), put(e))
        hlo = lowered.compile().as_text()
        assert "f8e5m2" in hlo, "fp8 payloads missing from lowered HLO"
        red, err = fn(put(g), put(e))
        true = np.asarray(g["w"]).mean(0)
        rel = np.linalg.norm(np.asarray(red["w"]) - true) \\
            / np.linalg.norm(true)
        assert rel < 0.15, rel
        print("OK lowering", rel)

        # 2. fp8 zero-gather: sharded master -> full params within e4m3
        #    quantization error, with f8e4m3 payloads in the HLO.
        mesh8 = make_mesh((8,), ("data",))
        plan8 = ParallelPlan.build(mesh8, DistConfig(wire_zero_gather="fp8"))
        w = jax.random.normal(jax.random.PRNGKey(1), (16, 24))
        mspec = plan8.master_specs({"w": w})["w"]
        assert "data" in tuple(mspec), mspec
        ws = jax.device_put(w, NamedSharding(mesh8, mspec))
        gathered = jax.jit(plan8.gather_params)({"w": ws})["w"]
        hlo2 = jax.jit(plan8.gather_params).lower(
            {"w": ws}).compile().as_text()
        assert "f8e4m3" in hlo2, "e4m3 gather payloads missing"
        relg = float(jnp.max(jnp.abs(gathered - w)) / jnp.max(jnp.abs(w)))
        assert relg < 0.10, relg
        print("OK gather", relg)

        # 3. fp8 wire + active TP is refused with a clear error on this JAX.
        meshtp = make_mesh((2, 4), ("data", "model"))
        try:
            ParallelPlan.build(meshtp, DistConfig(wire="fp8_ef"))
            raise AssertionError("fp8 wire + TP should be refused")
        except NotImplementedError as ex:
            assert "shard_map" in str(ex)
        # ...but disabling TP on the same mesh makes it legal.
        p = ParallelPlan.build(meshtp, DistConfig(wire="fp8_ef", tp=False))
        assert p.compresses and p.tp_size == 1
        print("OK gates")
    """)
    assert out.count("OK") == 3


@pytest.mark.slow
def test_wire_train_convergence_law():
    """The PR's convergence law: with policy.dist.wire='fp8_ef' on an
    8-device dp mesh, the loss trajectory matches the uncompressed run
    within enhanced-loss-scaling tolerance (the same batches, keys, and
    init — only the gradient reduction wire format differs)."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.precision_policy import DistConfig
        from repro.distributed.strategy import ParallelPlan
        from repro.launch.mesh import enter_mesh, make_mesh
        from repro.models.registry import build_config
        from repro.models.transformer import init_lm
        from repro.train.step import make_optimizer_for, make_train_step

        mesh = make_mesh((8,), ("data",))
        cfg = build_config("qwen2-1.5b", smoke=True).replace(
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab_size=512, remat=False)
        opt = make_optimizer_for(cfg, learning_rate=1e-3)
        plan_f = ParallelPlan.build(mesh, DistConfig(wire="full"))
        plan_w = ParallelPlan.build(mesh, DistConfig(wire="fp8_ef"))
        step_f = jax.jit(make_train_step(cfg, opt, plan=plan_f))
        step_w = jax.jit(make_train_step(cfg, opt, plan=plan_w))
        params = init_lm(jax.random.PRNGKey(0), cfg)
        sf = sw = opt.init(params)
        err = plan_w.init_wire_state(params)
        rng = np.random.default_rng(0)
        toks = rng.integers(0, cfg.vocab_size, (16, 32), dtype=np.int32)
        batch = {"tokens": toks, "labels": toks,
                 "loss_mask": np.ones((16, 32), np.float32)}
        rels, losses = [], []
        with enter_mesh(mesh):
            for i in range(12):
                k = jax.random.fold_in(jax.random.PRNGKey(7), i)
                sf, mf = step_f(sf, batch, k)
                (sw, err), mw = step_w(sw, err, batch, k)
                lf, lw = float(mf["loss"]), float(mw["loss"])
                losses.append(lf)
                rels.append(abs(lw - lf) / abs(lf))
        assert max(rels) < 2e-2, rels
        assert sum(rels) / len(rels) < 5e-3, rels
        # both actually train (same batch memorized): loss fell materially
        assert losses[-1] < losses[0] - 0.02, losses
        # error feedback is alive: residuals are nonzero after 12 steps
        amax = max(float(jnp.max(jnp.abs(x)))
                   for x in jax.tree_util.tree_leaves(err))
        assert amax > 0, amax
        print("OK", max(rels), lf)
    """)
    assert "OK" in out


@pytest.mark.slow
def test_wire_error_checkpoint_roundtrip():
    """Error-feedback residuals ride the checkpoint: an interrupted wire
    run restored mid-stream finishes bit-identical (master weights AND
    residual buffers) to the uninterrupted run."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from repro.core.precision_policy import DistConfig
        from repro.data import DataConfig, synthetic_lm_batches
        from repro.distributed.strategy import ParallelPlan
        from repro.launch.mesh import make_mesh
        from repro.models.registry import build_config
        from repro.train.loop import LoopConfig, TrainLoop
        from repro.train.step import make_optimizer_for

        mesh = make_mesh((8,), ("data",))
        cfg = build_config("qwen2-1.5b", smoke=True).replace(
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab_size=512, remat=False)
        plan = ParallelPlan.build(mesh, DistConfig(wire="fp8_ef"))

        def run(ckpt_dir, total):
            data = synthetic_lm_batches(DataConfig(
                vocab_size=cfg.vocab_size, seq_len=32, batch_size=16,
                seed=0))
            loop = TrainLoop(cfg, make_optimizer_for(cfg), data,
                             LoopConfig(total_steps=total,
                                        checkpoint_every=3,
                                        checkpoint_dir=ckpt_dir),
                             plan=plan)
            return loop.run()

        d1 = tempfile.mkdtemp(); d2 = tempfile.mkdtemp()
        a = run(d1, 6)                       # uninterrupted: 0..6
        run(d2, 3)                           # "preempted" at 3
        b = run(d2, 6)                       # restored from 3, to 6
        assert a["last_step"] == b["last_step"] == 6
        for xa, xb in zip(jax.tree_util.tree_leaves(a["state"].master),
                          jax.tree_util.tree_leaves(b["state"].master)):
            assert np.array_equal(np.asarray(xa), np.asarray(xb))
        ea = jax.tree_util.tree_leaves(a["wire_error"])
        eb = jax.tree_util.tree_leaves(b["wire_error"])
        assert ea and any(float(jnp.max(jnp.abs(x))) > 0 for x in ea)
        for xa, xb in zip(ea, eb):
            assert np.array_equal(np.asarray(xa), np.asarray(xb))
        print("OK bitexact")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_wire_build_cell_hierarchical_mesh():
    """launch.specs derives everything from the plan: a train cell with the
    policy.dist.wire override lowers and compiles on a (pod, data) mesh,
    threads the stacked residual through in/out shardings, and reports
    wire accounting in meta."""
    out = _run_subprocess("""
        import jax
        from repro.launch.mesh import enter_mesh, jit_shardings, make_mesh
        import repro.launch.specs as S
        import repro.models.registry as R
        S.SHAPES["tiny_train"] = dict(seq=64, batch=8, mode="train")
        orig = R.build_config
        R.build_config = lambda a, smoke=False, **kw: orig(a, smoke=True, **kw)
        S._cfg_for_cell.cache_clear()
        try:
            mesh = make_mesh((2, 4), ("pod", "data"))
            with enter_mesh(mesh):
                cell = S.build_cell(
                    "qwen2-1.5b", "tiny_train", mesh,
                    overrides={"policy.dist.wire": "fp8_ef",
                               "policy.dist.wire_zero_gather": "fp8"})
                meta = cell["meta"]
                assert meta["dist"]["compresses"], meta["dist"]
                assert meta["dist"]["wire_axis"] == "pod"
                assert meta["wire_bytes"]["ratio_fp8_vs_bf16"] <= 0.55
                assert len(cell["args"]) == 4   # state, err, batch, key
                c = jax.jit(cell["fn"],
                            in_shardings=jit_shardings(
                                mesh, cell["in_shardings"]),
                            out_shardings=jit_shardings(
                                mesh, cell["out_shardings"])
                            ).lower(*cell["args"]).compile()
                hlo = c.as_text()
                assert "f8e5m2" in hlo   # wire payloads are really 1 byte
                print("OK", meta["dist"])
        finally:
            R.build_config = orig
    """)
    assert "OK" in out
