"""Shared benchmark plumbing: reduced-scale trainers for the paper's
ablations (convnet + LM + seq2seq), result persistence."""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.loss_scale import LossScaler, convnet_scaler, underflow_fraction
from repro.core.master_weights import MixedPrecisionOptimizer
from repro.core.precision_policy import QuantConfig
from repro.data import (DataConfig, synthetic_image_batches,
                        synthetic_lm_batches, synthetic_seq2seq_batches)
from repro.models.resnet import ResNetConfig, init_resnet, resnet_loss
from repro.models.registry import build_config
from repro.models.transformer import init_lm, lm_loss
from repro.optim.optimizers import (AdamConfig, MomentumConfig,
                                    adam_leafwise, momentum_leafwise,
                                    adam, momentum_sgd)

RESULTS_DIR = Path("experiments/bench")
# Repo root, for the BENCH_<name>.json perf-trajectory files: detailed
# results live under experiments/bench/, but the headline perf numbers
# (tokens/s, step time, fused-vs-unfused GEMM ratio) are mirrored at the
# repo root so the trajectory is visible across PRs without digging.
REPO_ROOT = Path(__file__).resolve().parents[1]


def save_result(name: str, payload: dict):
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    (RESULTS_DIR / f"{name}.json").write_text(json.dumps(payload, indent=1))


def save_bench(name: str, payload: dict):
    """Persist a perf benchmark: full payload under experiments/bench/ AND
    the repo-root BENCH_<name>.json trajectory file."""
    save_result(name, payload)
    (REPO_ROOT / f"BENCH_{name}.json").write_text(
        json.dumps(payload, indent=1) + "\n")


def _mk_opt(name, lr, scaler, master_dtype="float16"):
    if name == "momentum":
        cfg = MomentumConfig(learning_rate=lr, momentum=0.9)
        init, update = momentum_sgd(cfg)
        names, leaf = momentum_leafwise(cfg)
    else:
        cfg = AdamConfig(learning_rate=lr)
        init, update = adam(cfg)
        names, leaf = adam_leafwise(cfg)
    return MixedPrecisionOptimizer(inner_init=init, inner_update=update,
                                   scaler=scaler, master_dtype=master_dtype,
                                   accum_names=names, leaf_update=leaf)


# ---------------------------------------------------------------------------
# convnet trainer (paper's ResNet experiments at CIFAR scale)
# ---------------------------------------------------------------------------

def train_convnet(*, quant: QuantConfig, scaler: LossScaler,
                  steps: int = 150, seed: int = 0, lr: float = 0.05,
                  include_l2: bool = True, weight_decay: float = 5e-4,
                  batch_size: int = 64, eval_every: int = 25,
                  track_underflow: bool = False) -> Dict:
    cfg = ResNetConfig(depth_per_stage=(1, 1), widths=(16, 32),
                       quant=quant, weight_decay=weight_decay)
    params = init_resnet(jax.random.PRNGKey(seed), cfg)
    opt = _mk_opt("momentum", lr, scaler)
    state = opt.init(params)
    # noise=1.6 keeps the task hard enough that precision/rounding ablations
    # separate (clean prototypes would saturate every run at 100%).
    train_it = synthetic_image_batches(batch_size=batch_size, image_size=16,
                                       seed=seed, noise=1.6)
    val_it = synthetic_image_batches(batch_size=256, image_size=16,
                                     seed=seed + 1000, noise=1.6)
    val_batch = next(val_it)

    def loss_fn(p, batch, key, scale):
        return resnet_loss(p, batch, cfg=cfg, qkey=key, loss_scale=scale,
                           include_l2=include_l2)

    @jax.jit
    def step_fn(state, batch, key):
        params = opt.compute_params(state)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch, key, state.loss_scale.scale)
        uf = underflow_fraction(grads, threshold=1.52587890625e-05) \
            if track_underflow else jnp.float32(0)
        new_state, opt_m = opt.apply_gradients(state, grads)
        return new_state, {**metrics, **opt_m, "underflow_frac": uf}

    @jax.jit
    def eval_fn(state, batch):
        params = opt.compute_params(state)
        _, metrics = resnet_loss(params, batch, cfg=cfg, qkey=None,
                                 include_l2=False)
        return metrics

    hist = {"step": [], "train_nll": [], "val_acc": [], "val_nll": [],
            "l2_loss": [], "loss_scale": [], "underflow_frac": [],
            "overflows": []}
    for i in range(steps):
        batch = next(train_it)
        state, m = step_fn(state, batch,
                           jax.random.fold_in(jax.random.PRNGKey(7), i))
        if i % eval_every == 0 or i == steps - 1:
            ev = eval_fn(state, val_batch)
            hist["step"].append(i)
            hist["train_nll"].append(float(m["nll"]))
            hist["val_acc"].append(float(ev["accuracy"]))
            hist["val_nll"].append(float(ev["nll"]))
            hist["l2_loss"].append(float(m["l2_loss"]))
            hist["loss_scale"].append(float(m["loss_scale"]))
            hist["underflow_frac"].append(float(m["underflow_frac"]))
            hist["overflows"].append(float(m["overflow_count"]))
    return hist


# ---------------------------------------------------------------------------
# LM / seq2seq trainer (paper's GNMT/Transformer experiments, reduced)
# ---------------------------------------------------------------------------

def train_lm(*, policy, steps: int = 80, seed: int = 0, lr: float = 3e-3,
             scaler: Optional[LossScaler] = None, seq2seq: bool = False,
             vocab: int = 128) -> Dict:
    arch = "paper-transformer" if seq2seq else "qwen2-1.5b"
    cfg = build_config(arch, smoke=True).replace(
        vocab_size=vocab, policy=policy, remat=False)
    if not seq2seq:
        cfg = cfg.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128)
    opt = _mk_opt("adam", lr, scaler or LossScaler(mode="enhanced",
                                                   init_scale=512.0,
                                                   min_scale_schedule=()))
    from repro.train.step import make_train_step
    step_fn = jax.jit(make_train_step(cfg, opt))
    if seq2seq:
        data = synthetic_seq2seq_batches(
            DataConfig(vocab_size=vocab, seq_len=33, batch_size=8,
                       seed=seed), d_model=cfg.d_model)
    else:
        data = synthetic_lm_batches(DataConfig(
            vocab_size=vocab, seq_len=32, batch_size=8, seed=seed))
    params = init_lm(jax.random.PRNGKey(seed), cfg)
    state = opt.init(params)
    hist = {"step": [], "loss": [], "loss_scale": [], "overflows": []}
    for i in range(steps):
        state, m = step_fn(state, next(data),
                           jax.random.fold_in(jax.random.PRNGKey(11), i))
        hist["step"].append(i)
        hist["loss"].append(float(m["loss"]))
        hist["loss_scale"].append(float(m["loss_scale"]))
        hist["overflows"].append(float(m["overflow_count"]))
    return hist


def timed(fn, *args, iters: int = 5) -> float:
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6  # us


def timed_min(fn, *args, reps: int = 10) -> float:
    """Best-of-single-calls wall time (us). A mean over a batched loop
    folds scheduler spikes into the estimate and penalizes multi-dispatch
    pipelines disproportionately; the per-call minimum is the standard
    noise-floor estimator for A/B wall comparisons (apply it to BOTH
    sides of a ratio)."""
    fn(*args)  # compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.time() - t0)
    return best * 1e6  # us
