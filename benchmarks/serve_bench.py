"""Paged serving-engine benchmark: end-to-end throughput / latency of
`PagedServeEngine` (chunked prefill + paged KV + on-device sampling) at
several concurrency levels, plus an exact prefix-cache reuse measurement.

Emits the repo-root BENCH_serve.json perf trajectory (see
benchmarks.common.save_bench): decode tokens/s and p50/p99 request latency
per concurrency level, page-pool occupancy, prefix-cache hit rate.

CPU numbers are correctness-scale (XLA interpret-path models), so the
trajectory tracks RELATIVE movement across PRs, same as the kernel bench.

  PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import save_bench
from repro.models.registry import build_config
from repro.models.transformer import init_lm
from repro.serve import PagedServeConfig, PagedServeEngine


def _bench_cfg(*, fp8_kv: bool):
    """Reduced-scale qwen2: big enough that the step does real work, small
    enough that a CPU run finishes in seconds."""
    cfg = build_config("qwen2-1.5b", smoke=True)
    cfg = cfg.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                      d_ff=256, vocab_size=512)
    if fp8_kv:
        cfg = cfg.replace(policy=dataclasses.replace(
            cfg.policy, kv_cache_format="e5m2"))
    return cfg


def _run_level(cfg, params, *, concurrency: int, n_requests: int,
               prompt_len: int, max_new: int, seed: int = 0):
    """Serve `n_requests` distinct prompts at `concurrency` parallel rows;
    returns the throughput/latency slice of the engine stats."""
    serve = PagedServeConfig(
        max_batch=concurrency, max_len=256, n_pages=128, page_size=16,
        chunk_size=32, temperature=0.0, prefix_cache=False)
    eng = PagedServeEngine(cfg, params, serve)
    rng = np.random.default_rng(seed)
    pending = [rng.integers(0, cfg.vocab_size, size=prompt_len)
               for _ in range(n_requests)]
    # Warm the jit cache outside the timed region (compile time would
    # otherwise dominate the first request's latency on CPU).
    eng.add_request(pending[0], max_new_tokens=2)
    eng.run_to_completion()
    t0 = time.perf_counter()
    while pending or any(s is not None for s in eng.slots):
        while pending and eng.free_slots():
            eng.add_request(pending.pop(0), max_new_tokens=max_new)
        eng.step()
    wall = time.perf_counter() - t0
    s = eng.stats()
    return {
        "concurrency": concurrency,
        "n_requests": n_requests,
        "prompt_len": prompt_len,
        "max_new_tokens": max_new,
        "wall_s": wall,
        "decode_tokens_per_s": s["decode_tokens_per_s"],
        "total_tokens_per_s": (s["prefill_tokens"] + s["decode_tokens"])
                              / wall,
        "request_latency_s": s["request_latency_s"],
        "prefill_latency_s": s["prefill_latency_s"],
        "step_s": s["step_s"],
        "page_occupancy": s["page_occupancy"],
    }


def _run_prefix_cache(cfg, params, *, prompt_len: int, max_new: int,
                      n_repeats: int):
    """Same long prompt served repeatedly: every request after the first
    should splice the cached full-page prefix (cold prefill only once)."""
    serve = PagedServeConfig(
        max_batch=2, max_len=256, n_pages=128, page_size=16,
        chunk_size=32, temperature=0.0, prefix_cache=True)
    eng = PagedServeEngine(cfg, params, serve)
    prompt = np.arange(prompt_len) % cfg.vocab_size
    lat = []
    for _ in range(n_repeats):
        t0 = time.perf_counter()
        eng.add_request(prompt, max_new_tokens=max_new)
        eng.run_to_completion()
        lat.append(time.perf_counter() - t0)
    s = eng.stats()
    return {
        "prompt_len": prompt_len,
        "n_repeats": n_repeats,
        "cold_request_s": lat[0],
        "warm_request_s_p50": float(np.percentile(lat[1:], 50)),
        "warm_speedup": lat[0] / float(np.percentile(lat[1:], 50)),
        "prefix_cache_hit_rate": s["prefix_cache_hit_rate"],
        "prefix_cache_entries": s["prefix_cache_entries"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="nightly-CI scale: fewer/shorter requests")
    ap.add_argument("--fp8-kv", action="store_true")
    args = ap.parse_args()

    cfg = _bench_cfg(fp8_kv=args.fp8_kv)
    params = init_lm(jax.random.PRNGKey(0), cfg)

    if args.smoke:
        levels, n_req, plen, max_new, reps = [2, 4], 6, 24, 8, 3
    else:
        levels, n_req, plen, max_new, reps = [2, 4, 8], 16, 48, 24, 6

    payload = {
        "bench": "paged_serving_engine",
        "model": {"arch": "qwen2-1.5b[reduced]", "n_layers": cfg.n_layers,
                  "d_model": cfg.d_model,
                  "kv_cache_format": cfg.policy.kv_cache_format,
                  "recipe": cfg.policy.quant.recipe},
        "levels": [],
    }
    for c in levels:
        r = _run_level(cfg, params, concurrency=c, n_requests=n_req,
                       prompt_len=plen, max_new=max_new)
        payload["levels"].append(r)
        print(f"concurrency={c}: {r['decode_tokens_per_s']:.1f} decode "
              f"tok/s, request p50={r['request_latency_s']['p50']:.3f}s "
              f"p99={r['request_latency_s']['p99']:.3f}s")
    payload["prefix_cache"] = _run_prefix_cache(
        cfg, params, prompt_len=plen, max_new=max_new, n_repeats=reps)
    print(f"prefix cache: hit_rate="
          f"{payload['prefix_cache']['prefix_cache_hit_rate']:.2f}, "
          f"warm speedup {payload['prefix_cache']['warm_speedup']:.2f}x")
    save_bench("serve", payload)
    print("wrote BENCH_serve.json")


if __name__ == "__main__":
    main()
