"""One benchmark per paper table/figure (reduced scale, CPU-runnable).

  table1  — dynamic range of FP8 vs FP16/FP32 (exact check).
  fig2a   — ResNet convergence vs constant loss-scale {1, 1k, 4k, 10k}:
            gradient-underflow fraction + final validation accuracy.
  fig2b   — enhanced dynamic scaling: min-threshold schedule trace.
  fig3    — RNE-only FP8: validation gap + L2-loss growth vs FP32 baseline.
  fig4    — stochastic rounding + L2 recovers the baseline.
  table2  — FP8 vs FP32 convnet validation accuracy.
  table3  — recipe comparison (W/A/E/G + master dtype) — ours vs RNE-only.
  table4  — seq2seq transformer: FP8 vs FP32 loss parity.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import save_result, train_convnet, train_lm
from repro.core.fp8_formats import table1 as fmt_table1
from repro.core.loss_scale import LossScaler, convnet_scaler, gnmt_scaler
from repro.core.precision_policy import (BASELINE, BASELINE_POLICY,
                                         PAPER_FP8, PAPER_FP8_RNE,
                                         PAPER_POLICY, PrecisionPolicy)

FAST = dict(steps=120, eval_every=20)


def bench_table1():
    t = fmt_table1()
    expected = {
        "fp32": dict(max_normal=3.40e38, min_normal=1.17e-38,
                     min_subnormal=1.40e-45),
        "fp16": dict(max_normal=65504.0, min_normal=6.10e-5,
                     min_subnormal=5.96e-8),
        "e5m2": dict(max_normal=57344.0, min_normal=6.10e-5,
                     min_subnormal=1.52e-5),
    }
    ok = all(np.isclose(t[k][f], expected[k][f], rtol=1e-2)
             for k in expected for f in expected[k])
    save_result("table1", {"computed": {k: {f: float(v) for f, v in
                                            row.items() if f != "bit_format"}
                                        for k, row in t.items()},
                           "matches_paper": bool(ok)})
    print(f"table1: dynamic ranges match paper: {ok}")
    return ok


def bench_fig2a():
    """Constant loss-scale sweep on the reduced convnet (paper: ResNet-50
    diverges at 1000, converges at 10000)."""
    out = {}
    for scale in [1.0, 1000.0, 4000.0, 10000.0]:
        hist = train_convnet(quant=PAPER_FP8, scaler=convnet_scaler(scale),
                             track_underflow=True, **FAST)
        out[str(int(scale))] = {
            "final_val_acc": hist["val_acc"][-1],
            "mean_underflow_frac": float(np.mean(hist["underflow_frac"])),
            "final_train_nll": hist["train_nll"][-1],
        }
        print(f"fig2a scale={scale:>7.0f}: val_acc={hist['val_acc'][-1]:.3f} "
              f"underflow={np.mean(hist['underflow_frac']):.4f}")
    save_result("fig2a", out)
    return out


def bench_fig2b():
    """Enhanced dynamic scaling trace: the scheduled min threshold rises."""
    s = gnmt_scaler()
    trace = []
    st = s.init()
    import dataclasses as dc
    import jax.numpy as jnp
    # simulate a noisy run: overflow every 9th step; schedule knots at
    # 40K/150K are exercised by fast-forwarding the step counter.
    for step in [0, 10_000, 39_999, 40_001, 100_000, 150_001, 200_000]:
        st = dc.replace(st, step=jnp.asarray(step))
        st_over = s.update(st, jnp.asarray(False))       # an overflow event
        trace.append({"step": step, "floor": float(s.min_scale_at(
            jnp.asarray(step))), "scale_after_overflow": float(st_over.scale)})
    save_result("fig2b", {"trace": trace})
    for t in trace:
        print(f"fig2b step={t['step']:>7d} floor={t['floor']:>8.0f} "
              f"after-overflow={t['scale_after_overflow']:>8.0f}")
    return trace


def bench_fig3_fig4():
    """RNE-only vs SR+L2 vs FP32: validation gap and L2 growth (Fig 3/4)."""
    runs = {
        "fp32_baseline": dict(quant=BASELINE, scaler=convnet_scaler(1.0)),
        "fp8_rne_l2": dict(quant=PAPER_FP8_RNE,
                           scaler=convnet_scaler(10_000.0)),
        "fp8_rne_noreg": dict(quant=PAPER_FP8_RNE,
                              scaler=convnet_scaler(10_000.0),
                              include_l2=False, weight_decay=0.0),
        "fp8_sr_l2": dict(quant=PAPER_FP8, scaler=convnet_scaler(10_000.0)),
    }
    out = {}
    for name, kw in runs.items():
        hist = train_convnet(seed=1, **kw, **FAST)
        out[name] = {
            "final_val_acc": hist["val_acc"][-1],
            "final_val_nll": hist["val_nll"][-1],
            "final_train_nll": hist["train_nll"][-1],
            "l2_trajectory": hist["l2_loss"],
            "val_gap": hist["val_nll"][-1] - hist["train_nll"][-1],
        }
        print(f"fig3/4 {name:16s}: val_acc={hist['val_acc'][-1]:.3f} "
              f"gap={out[name]['val_gap']:.3f} "
              f"l2_final={hist['l2_loss'][-1]:.4f}")
    save_result("fig3_fig4", out)
    return out


def bench_table2():
    """FP8 (full recipe) vs FP32 accuracy — paper Table 2 analogue."""
    accs = {}
    for name, quant, scaler in [
            ("fp32", BASELINE, convnet_scaler(1.0)),
            ("fp8", PAPER_FP8, convnet_scaler(10_000.0))]:
        hist = train_convnet(quant=quant, scaler=scaler, seed=2,
                             steps=150, eval_every=25)
        accs[name] = hist["val_acc"][-1]
        print(f"table2 {name}: val_acc={accs[name]:.3f}")
    accs["fp8_minus_fp32"] = accs["fp8"] - accs["fp32"]
    save_result("table2", accs)
    return accs


def bench_table3():
    """Recipe comparison (paper Table 3: ours vs Wang et al.): here the
    controlled comparison is our full recipe (SR) vs the RNE-only recipe at
    the same W/A/E/G=8,8,8,8 + fp16 master setting."""
    out = {}
    for name, quant in [("ours_sr", PAPER_FP8), ("rne_only", PAPER_FP8_RNE)]:
        hist = train_convnet(quant=quant, scaler=convnet_scaler(10_000.0),
                             seed=3, steps=150, eval_every=25)
        out[name] = {"val_err": 1.0 - hist["val_acc"][-1]}
        print(f"table3 {name}: top-1 err={out[name]['val_err']:.3f}")
    save_result("table3", out)
    return out


def bench_table4():
    """Seq2seq transformer FP8 vs FP32 loss parity (paper Table 4 BLEU)."""
    out = {}
    for name, pol in [("fp32", BASELINE_POLICY), ("fp8", PAPER_POLICY)]:
        hist = train_lm(policy=pol, seq2seq=True, steps=80)
        final = float(np.mean(hist["loss"][-10:]))
        out[name] = {"final_loss": final}
        print(f"table4 {name}: final_loss={final:.4f}")
    out["ratio"] = out["fp8"]["final_loss"] / out["fp32"]["final_loss"]
    save_result("table4", out)
    return out
