"""Wire-format communication benchmark: fp8_ef vs full DP reduction.

  PYTHONPATH=src python -m benchmarks.comm_bench            # full sweep
  PYTHONPATH=src python -m benchmarks.comm_bench --smoke    # CI nightly

Per data-parallel size (2 / 4 / 8 host devices): wire bytes of the DP
gradient reduction (bf16 baseline vs the e5m2 error-feedback collective —
the fp8 payloads are real 1-byte dtypes in the HLO, so the model ratio is
what actually moves), wall-clock of both collectives on a grad-sized
pytree, and a tiny end-to-end train-step A/B (policy.dist.wire full vs
fp8_ef) with the loss divergence after a few steps. Results join the
perf trajectory as BENCH_comm.json.

Must own the process: forces an 8-device host platform before the first
jax import (benchmarks.run invokes it as a subprocess for this reason).
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import time

import numpy as np


def _median_time(fn, *args, iters=5):
    import jax
    jax.block_until_ready(fn(*args))          # compile
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def bench_collectives(dp_sizes, *, leaf_shapes, iters):
    """Sweep the stacked-contract collectives over dp sizes."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh

    from repro.core.precision_policy import DistConfig
    from repro.distributed.grad_compress import wire_bytes_model
    from repro.distributed.strategy import ParallelPlan

    rng = np.random.default_rng(0)
    grads = {f"w{i}": jnp.asarray(rng.standard_normal(s), jnp.float32)
             for i, s in enumerate(leaf_shapes)}
    rows = []
    for dp in dp_sizes:
        if dp > jax.device_count():
            continue
        mesh = Mesh(np.array(jax.devices()[:dp]), ("data",))
        plan = ParallelPlan.build(mesh, DistConfig(wire="fp8_ef"))
        stacked = jax.tree_util.tree_map(
            lambda g: jnp.broadcast_to(g[None], (dp,) + g.shape), grads)
        err = jax.tree_util.tree_map(
            lambda g: jnp.zeros((dp,) + g.shape, jnp.float32), grads)
        full = jax.jit(plan.dp_allreduce(wire="full"))
        fp8 = jax.jit(plan.dp_allreduce(wire="fp8_ef"))
        # correctness: identical contributions -> compressed mean == leaf
        red, _ = fp8(stacked, err)
        rel = max(float(jnp.max(jnp.abs(r - g))
                        / jnp.maximum(jnp.max(jnp.abs(g)), 1e-9))
                  for r, g in zip(jax.tree_util.tree_leaves(red),
                                  jax.tree_util.tree_leaves(grads)))
        row = dict(dp=dp,
                   t_full_s=_median_time(full, stacked, err, iters=iters),
                   t_fp8_s=_median_time(fp8, stacked, err, iters=iters),
                   max_rel_err=rel,
                   **wire_bytes_model(grads, dp))
        print(f"comm,dp={dp},ratio={row['ratio_fp8_vs_bf16']:.3f},"
              f"t_full={row['t_full_s']:.4f}s,t_fp8={row['t_fp8_s']:.4f}s,"
              f"rel_err={rel:.2e}")
        rows.append(row)
    return rows


def bench_train_step(*, steps, iters):
    """End-to-end A/B: the same tiny model trained with wire=full vs
    wire=fp8_ef on the widest available dp mesh."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh

    from repro.core.loss_scale import LossScaler
    from repro.core.precision_policy import DistConfig
    from repro.models.registry import build_config
    from repro.models.transformer import init_lm
    from repro.train.step import make_optimizer_for, make_train_step

    dp = jax.device_count()
    if dp < 2:
        return None
    mesh = Mesh(np.array(jax.devices()), ("data",))
    cfg = build_config("qwen2-1.5b", smoke=True).replace(remat=False)
    opt = make_optimizer_for(cfg, scaler=LossScaler(mode="enhanced",
                                                    init_scale=2.0**8))
    params = init_lm(jax.random.PRNGKey(0), cfg)
    state0 = opt.init(params)
    rng = np.random.default_rng(1)
    B, T = 2 * dp, 32
    batch = {"tokens": rng.integers(0, cfg.vocab_size, (B, T), dtype=np.int32),
             "labels": rng.integers(0, cfg.vocab_size, (B, T),
                                    dtype=np.int32),
             "loss_mask": np.ones((B, T), np.float32)}

    from repro.distributed.strategy import ParallelPlan
    plan_f = ParallelPlan.build(mesh, DistConfig(wire="full"))
    plan_w = ParallelPlan.build(mesh, DistConfig(wire="fp8_ef"))
    step_f = jax.jit(make_train_step(cfg, opt, plan=plan_f))
    step_w = jax.jit(make_train_step(cfg, opt, plan=plan_w))
    key = jax.random.PRNGKey(2)

    sf, lf = state0, None
    sw, lw = state0, None
    err = plan_w.init_wire_state(state0.master)
    for i in range(steps):
        k = jax.random.fold_in(key, i)
        sf, mf = step_f(sf, batch, k)
        (sw, err), mw = step_w(sw, err, batch, k)
        lf, lw = float(mf["loss"]), float(mw["loss"])
    t_full = _median_time(step_f, sf, batch, key, iters=iters)
    t_fp8 = _median_time(step_w, sw, err, batch, key, iters=iters)
    out = dict(dp=dp, steps=steps, t_full_s=t_full, t_fp8_s=t_fp8,
               loss_full=lf, loss_fp8=lw,
               loss_rel_diff=abs(lw - lf) / max(abs(lf), 1e-9),
               wire_bytes=plan_w.wire_bytes(state0.master))
    print(f"comm,train_step,dp={dp},t_full={t_full:.4f}s,t_fp8={t_fp8:.4f}s,"
          f"loss_rel_diff={out['loss_rel_diff']:.3e}")
    return out


def bench_comm(smoke: bool = False):
    import jax

    from benchmarks.common import save_bench
    leaf_shapes = [(128, 128), (333,), (64, 65)] if smoke \
        else [(512, 512), (512, 512), (2048, 513), (4099,)]
    iters = 3 if smoke else 7
    payload = {
        "device_count": jax.device_count(),
        "platform": jax.devices()[0].platform,
        "smoke": smoke,
        "sweep": bench_collectives((2, 4, 8), leaf_shapes=leaf_shapes,
                                   iters=iters),
        "train_step": bench_train_step(steps=4 if smoke else 8, iters=iters),
    }
    save_bench("comm", payload)
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes (CI nightly)")
    args = ap.parse_args(argv)
    bench_comm(smoke=args.smoke)


if __name__ == "__main__":
    main()
