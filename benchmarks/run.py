"""Benchmark entry point: one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run table2     # one

Prints name,value CSV lines; detailed JSON under experiments/bench/.
"""
import sys
import time

from benchmarks import paper_tables
from benchmarks.kernel_bench import bench_kernels, bench_speed

ALL = {
    "table1": paper_tables.bench_table1,
    "fig2a": paper_tables.bench_fig2a,
    "fig2b": paper_tables.bench_fig2b,
    "fig3_fig4": paper_tables.bench_fig3_fig4,
    "table2": paper_tables.bench_table2,
    "table3": paper_tables.bench_table3,
    "table4": paper_tables.bench_table4,
    # Perf trajectory (repo-root BENCH_*.json): kernel fused-vs-unfused +
    # reduced-scale training tokens/s and step time.
    "kernels": bench_kernels,
    "speed": bench_speed,
}


def main() -> None:
    names = sys.argv[1:] or list(ALL)
    for name in names:
        t0 = time.time()
        print(f"=== {name} ===")
        ALL[name]()
        print(f"{name},elapsed_s,{time.time() - t0:.1f}")


if __name__ == "__main__":
    main()
