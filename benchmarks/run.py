"""Benchmark entry point: one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run table2     # one

Prints name,value CSV lines; detailed JSON under experiments/bench/.
"""
import subprocess
import sys
import time

from benchmarks import paper_tables
from benchmarks.kernel_bench import bench_kernels, bench_speed


def bench_comm():
    """Wire-format collectives need an 8-device host platform, which must be
    set before jax initializes — run the comm bench in its own process."""
    import os
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu", PYTHONPATH="src")
    subprocess.run([sys.executable, "-m", "benchmarks.comm_bench", "--smoke"],
                   check=True, env=env)


ALL = {
    "table1": paper_tables.bench_table1,
    "fig2a": paper_tables.bench_fig2a,
    "fig2b": paper_tables.bench_fig2b,
    "fig3_fig4": paper_tables.bench_fig3_fig4,
    "table2": paper_tables.bench_table2,
    "table3": paper_tables.bench_table3,
    "table4": paper_tables.bench_table4,
    # Perf trajectory (repo-root BENCH_*.json): kernel fused-vs-unfused +
    # reduced-scale training tokens/s and step time.
    "kernels": bench_kernels,
    "speed": bench_speed,
    # Wire-format collectives: fp8_ef vs full DP reduction (BENCH_comm.json).
    "comm": bench_comm,
}


def main() -> None:
    names = sys.argv[1:] or list(ALL)
    for name in names:
        t0 = time.time()
        print(f"=== {name} ===")
        ALL[name]()
        print(f"{name},elapsed_s,{time.time() - t0:.1f}")


if __name__ == "__main__":
    main()
