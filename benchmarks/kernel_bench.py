"""Kernel micro-bench: XLA-path FP8 ops wall time on CPU (correctness-scale;
TPU numbers come from the dry-run roofline, not wall time) + shape sweep of
the Pallas kernels in interpret mode."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result, timed
from repro.core.quantize import quantize_rne, quantize_sr_e5m2
from repro.kernels.fp8_matmul import fp8_matmul, fp8_matmul_ref


def bench_kernels():
    out = {}
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1024, 1024), jnp.float32)

    rne = jax.jit(lambda v: quantize_rne(v))
    out["quantize_rne_1M_us"] = timed(rne, x)
    sr = jax.jit(lambda v, k: quantize_sr_e5m2(v, k))
    out["quantize_sr_1M_us"] = timed(sr, x, key)

    a8 = x.astype(jnp.float8_e5m2)
    b8 = jax.random.normal(key, (1024, 512), jnp.float32).astype(
        jnp.float8_e5m2)
    ref = jax.jit(lambda a, b: fp8_matmul_ref(a, b))
    out["fp8_matmul_xla_1024x1024x512_us"] = timed(ref, a8, b8)

    # Pallas interpret-mode correctness sweep (wall time is interpreter
    # overhead; recorded for completeness only).
    errs = []
    for m, k, n in [(64, 256, 128), (128, 512, 256)]:
        a = jax.random.normal(jax.random.PRNGKey(1), (m, k)).astype(
            jnp.float8_e5m2)
        b = jax.random.normal(jax.random.PRNGKey(2), (k, n)).astype(
            jnp.float8_e5m2)
        y = fp8_matmul(a, b, bm=64, bk=128, bn=128, interpret=True)
        r = fp8_matmul_ref(a, b)
        errs.append(float(jnp.abs(y - r).max()))
    out["pallas_interpret_max_abs_err"] = max(errs)
    save_result("kernels", out)
    for k, v in out.items():
        print(f"kernels {k}: {v:.3f}")
    return out
