"""Kernel micro-bench: XLA-path FP8 ops wall time on CPU (correctness-scale;
TPU numbers come from the dry-run roofline, not wall time), a fused-vs-
unfused quantize-epilogue comparison, and a shape/layout sweep of the Pallas
kernels in interpret mode.

Emits the repo-root BENCH_kernels.json / BENCH_train_speed.json perf
trajectory (see benchmarks.common.save_bench).

  PYTHONPATH=src python -m benchmarks.kernel_bench [--smoke]
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_bench, timed, timed_min
from repro.core.quantize import (fp8_amax_bits, quantize_rne,
                                 quantize_sr_e5m2, sr_fp8_via_f16)
from repro.core.fp8_formats import get_format
from repro.kernels.fp8_matmul import fp8_matmul, fp8_matmul_ref
from repro.kernels.fused_quant_matmul import (fused_quant_matmul,
                                              fused_quant_matmul_ref)


def bench_fused_vs_unfused(*, m=512, k=512, n=512, iters=10):
    """Fused quantize-in-epilogue GEMM vs the unfused composition.

    On CPU the comparison runs the XLA analogue of the two dataflows: the
    unfused side is three separately-jitted passes (GEMM -> materialize f32
    -> Q pass -> amax pass), forcing the output round-trip the fused
    epilogue eliminates; the fused side is the blocked analogue of the
    kernel schedule (kernels.autotune.make_gemm_analogue — tile dots with
    the quantize fused into the epilogue of one program; the amax pass is
    modelled identically on both sides), timed at the built-in default
    blocks AND at the autotuner's
    winners-table blocks for this shape. The tuned ratio is the headline
    fused-vs-unfused number of the BENCH trajectory (TPU wall time comes
    from the roofline dry-run, where the fused path additionally removes
    5 bytes/element of HBM epilogue traffic)."""
    from repro.kernels import autotune as at
    from repro.kernels.fused_quant_matmul import kernel as fqk
    a8 = (jax.random.normal(jax.random.PRNGKey(0), (m, k)) * 0.25).astype(
        jnp.float8_e5m2)
    b8 = (jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.1).astype(
        jnp.float8_e5m2)
    key = jax.random.PRNGKey(2)
    scale = jnp.float32(2.0)
    rand8 = jax.random.bits(key, (m, n), jnp.uint8)
    fmt = get_format("e5m2")

    gemm = jax.jit(lambda a, b: fp8_matmul_ref(a, b))
    qpass = jax.jit(lambda y, r: sr_fp8_via_f16(y * (1.0 / scale), r, fmt))
    apass = jax.jit(lambda q: fp8_amax_bits(q) * scale)

    def unfused(a, b, r):
        # Three separate jitted programs: each consumer reads its producer's
        # materialized output buffer — the HBM round-trips the fused
        # epilogue eliminates. No host syncs inside (those would only
        # measure dispatch latency); the timing loop syncs once at the end.
        y = gemm(a, b)        # materialize f32 output
        q = qpass(y, r)       # separate Q pass
        amax = apass(q)       # separate amax pass
        return q, amax

    defaults = (fqk.DEFAULT_BM, fqk.DEFAULT_BK, fqk.DEFAULT_BN)
    dflt = (min(defaults[0], max(8, m)), min(defaults[1], max(128, k)),
            min(defaults[2], max(128, n)))
    tuned = at.resolve_gemm_blocks("nn", m, k, n, out_format="e5m2",
                                   autotune="table", defaults=defaults)
    tuned = (min(tuned[0], max(8, m)), min(tuned[1], max(128, k)),
             min(tuned[2], max(128, n)))
    fused = at.make_gemm_analogue(m, k, n, dims="nn", bm=dflt[0],
                                  bk=dflt[1], bn=dflt[2])
    fused_t = at.make_gemm_analogue(m, k, n, dims="nn", bm=tuned[0],
                                    bk=tuned[1], bn=tuned[2])

    # Best-of-single-calls on both sides: CPU wall times jitter by tens
    # of percent, and the trajectory file should not record scheduler
    # noise as a perf regression (timed_min is the standard noise-floor
    # estimator, applied symmetrically to every side of the ratios).
    out_u = unfused(a8, b8, rand8)
    # Interleaved rounds: process-wide allocator/cache state drifts over a
    # bench run and can put one side's buffers in a slow placement for a
    # whole stretch — alternating the three programs and taking the min
    # across rounds samples every program under the same states.
    unfused_us = fused_us = tuned_us = float("inf")
    for _ in range(3):
        unfused_us = min(unfused_us,
                         timed_min(unfused, a8, b8, rand8, reps=iters))
        fused_us = min(fused_us,
                       timed_min(fused, a8, b8, rand8, scale, reps=iters))
        tuned_us = min(tuned_us,
                       timed_min(fused_t, a8, b8, rand8, scale, reps=iters))
    if tuned == dflt:
        # Same program measured twice — fold the repeats (noise only).
        tuned_us = fused_us = min(tuned_us, fused_us)

    # Bit parity of the single-fusion oracle against the unfused passes
    # (the blocked analogues above are timing models; the BIT contract of
    # every tuned config is gated on the real kernel in interpret mode by
    # the autotune sweep and tests/test_autotune.py).
    q_u, amax_u = out_u
    q_f, amax_f = fused_quant_matmul_ref(a8, b8, rand8,
                                         scale.reshape((1,)),
                                         with_amax=True)
    return {
        "shape_mkn": [m, k, n],
        "unfused_us": unfused_us,
        "fused_us": fused_us,
        "fused_tuned_us": tuned_us,
        "tuned_blocks_mkn": list(tuned),
        "default_blocks_mkn": list(dflt),
        "tuned_vs_default_ratio": fused_us / max(tuned_us, 1e-9),
        "fused_vs_unfused_gemm_ratio": unfused_us / max(tuned_us, 1e-9),
        "bitwise_equal": bool(
            (np.asarray(q_u).view(np.uint8)
             == np.asarray(q_f).view(np.uint8)).all()),
        # ref's fused amax is in grid units; the unfused amax pass de-scales.
        "amax_equal": float(amax_u) == float(amax_f * scale),
        # The quantity the fused kernel actually optimizes (CPU wall time
        # cannot model it): HBM bytes the epilogue moves per element —
        # unfused writes the f32 GEMM output, re-reads it for the Q pass
        # and writes fp8 (4+4+1) vs the fused kernel's single fp8 write.
        "model_epilogue_hbm_bytes_ratio": 9.0,
    }


def bench_pallas_sweep(*, smoke=False):
    """Interpret-mode bit-parity sweep of the fused kernel's three GEMM
    layouts (fwd nn / dgrad nt / wgrad tn) against the unfused composition
    oracle — wall time is interpreter overhead; the recorded signal is the
    parity bits."""
    shapes = [(64, 256, 128)] if smoke else [(64, 256, 128), (100, 300, 130)]
    out = {}
    for m, k, n in shapes:
        for dims, ash, bsh in [("nn", (m, k), (k, n)),
                               ("nt", (m, k), (n, k)),
                               ("tn", (k, m), (k, n))]:
            a = (jax.random.normal(jax.random.PRNGKey(0), ash) * 0.25
                 ).astype(jnp.float8_e5m2)
            b = (jax.random.normal(jax.random.PRNGKey(1), bsh) * 0.1
                 ).astype(jnp.float8_e5m2)
            key = jax.random.PRNGKey(2)
            y, amax = fused_quant_matmul(
                a, b, key, jnp.array([2.0]), dims=dims, bm=32, bk=128,
                bn=128, rounding="sr", with_amax=True, amax_units="grid",
                interpret=True)
            rand8 = jax.random.bits(key, y.shape, jnp.uint8)
            ref, ramax = fused_quant_matmul_ref(
                a, b, rand8, jnp.array([2.0]), dims=dims, rounding="sr",
                with_amax=True)
            bit_eq = bool((np.asarray(y).view(np.uint8)
                           == np.asarray(ref).view(np.uint8)).all())
            out[f"{dims}_{m}x{k}x{n}_bit_equal"] = bit_eq \
                and float(amax) == float(ramax)
    return out


def bench_attention(*, smoke=False):
    """Fused FP8 flash-attention vs the unfused S/P-materializing
    composition.

    On CPU the wall comparison runs the XLA analogues of the dataflows
    (same methodology as bench_fused_vs_unfused): the unfused side is four
    separately-jitted passes (QK^T scores -> Q pass on S -> softmax + Q
    pass on P -> PV), each consumer reading its producer's materialized
    S/P-shaped buffer; the fused side is the blocked one-pass
    online-softmax analogue of the kernel schedule
    (kernels.autotune.make_attn_analogue: per q-tile row, the causal
    strip of kv stripes is scored once and consumed once, S/P quantized
    per strip with the amax read once), timed at the kernel-default blocks
    AND at the autotuner winners-table blocks. The retired two-pass
    schedule (a second score pass over every stripe) is timed alongside —
    `one_pass_vs_two_pass_wall_ratio` is the honest cost of the extra
    pass the one-pass restructure removed. The recorded signal is those
    wall ratios plus the interpret-mode parity bits of the actual Pallas
    kernels against the oracle, and the modeled HBM bytes the kernel
    never moves (S f32 write+read, S8 write+read, P f32 write+read, P8
    write+read per score element — the kernel writes only the (Q, D)
    output)."""
    from repro.kernels import autotune as at
    from repro.kernels.fp8_attention import (fp8_attention_bwd,
                                             fp8_attention_bwd_ref,
                                             fp8_attention_fwd,
                                             fp8_attention_fwd_ref)
    from repro.kernels.fp8_attention import ref as attn_ref
    b, h, hkv, s, d = (1, 2, 1, 128, 64) if smoke else (2, 4, 2, 256, 64)
    q8, k8, v8 = [(jax.random.normal(jax.random.PRNGKey(i),
                                     (b, h if i == 0 else hkv, s, d))
                   * 0.3).astype(jnp.float8_e4m3fn) for i in range(3)]
    seed = jnp.uint32(7)
    scal = jnp.array([0.5, 2.0, 8.0, 0.25], jnp.float32)
    kw = dict(mask_mode="causal", fmt_s="e4m3", fmt_p="e4m3",
              rounding_s="sr", rounding_p="sr")
    fmt = get_format("e4m3")

    # Unfused XLA analogue: separately-jitted passes with materialized S/P
    # (RNE quantize on both sides so the Q-node cost is identical in the
    # unfused and the blocked fused analogues — same convention as
    # bench_attention_long).
    mask = jnp.arange(s)[None, :] <= jnp.arange(s)[:, None]

    def rep(x):
        return jnp.repeat(x, h // hkv, axis=1)

    scores = jax.jit(lambda q, k: jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.bfloat16),
        rep(k).astype(jnp.bfloat16), preferred_element_type=jnp.float32))
    qpass_s = jax.jit(lambda y: quantize_rne(y * scal[0], fmt))
    softq = jax.jit(lambda s8: quantize_rne(
        jax.nn.softmax(jnp.where(mask, s8.astype(jnp.float32) * scal[1],
                                 -1e30), axis=-1) * scal[2], fmt))
    pv = jax.jit(lambda p8, v: jnp.einsum(
        "bhqk,bhkd->bhqd", p8.astype(jnp.bfloat16),
        rep(v).astype(jnp.bfloat16),
        preferred_element_type=jnp.float32) * scal[3])

    def unfused(q, k, v):
        y = scores(q, k)          # materialize f32 S
        s8 = qpass_s(y)           # separate Q pass
        p8 = softq(s8)            # softmax + Q pass on P
        return pv(p8, v)          # PV from materialized P8

    # Fused analogues: blocked one-pass / two-pass kernel schedules over
    # the flattened (B*H, S, D) heads, at default and at tuned blocks.
    dflt = (min(at.TQ, s), attn_ref.resolve_block_kv(s, None))
    tq, tkv = at.resolve_attn_blocks("fwd", "causal", s, s, d,
                                     autotune="table")
    tuned = (min(tq, s), attn_ref.resolve_block_kv(s, tkv))
    qf = q8.reshape(b * h, s, d)
    kf = rep(k8).reshape(b * h, s, d)
    vf = rep(v8).reshape(b * h, s, d)
    one_pass = at.make_attn_analogue(s, d, bq=dflt[0], bkv=dflt[1],
                                     passes=1, fmt="e4m3")
    two_pass = at.make_attn_analogue(s, d, bq=dflt[0], bkv=dflt[1],
                                     passes=2, fmt="e4m3")
    one_pass_t = at.make_attn_analogue(s, d, bq=tuned[0], bkv=tuned[1],
                                       passes=1, fmt="e4m3")

    # Best-of-single-calls on every side (see bench_fused_vs_unfused on
    # wall-time noise; the mean-over-a-loop estimator penalizes the
    # multi-dispatch blocked pipelines disproportionately).
    reps = 15 if smoke else 30
    unfused_us = timed_min(unfused, q8, k8, v8, reps=reps)
    fused_us = timed_min(one_pass, qf, kf, vf, reps=reps)
    two_pass_us = timed_min(two_pass, qf, kf, vf, reps=reps)
    tuned_us = timed_min(one_pass_t, qf, kf, vf, reps=reps)
    if tuned == dflt:
        # Same program measured twice — fold the repeats (noise only).
        tuned_us = fused_us = min(tuned_us, fused_us)

    # Interpret-mode parity of the actual Pallas kernels vs the oracle.
    o, a_s, a_p = fp8_attention_fwd(q8, k8, v8, seed, scal,
                                    interpret=True, **kw)
    ro, ra_s, ra_p, _, _ = fp8_attention_fwd_ref(q8, k8, v8, seed, scal,
                                                 **kw)
    fwd_eq = bool((np.asarray(o).view(np.uint8)
                   == np.asarray(ro).view(np.uint8)).all()) \
        and float(a_s) == float(ra_s) and float(a_p) == float(ra_p)
    do8 = (jax.random.normal(jax.random.PRNGKey(4), (b, h, s, d))
           * 0.2).astype(jnp.float8_e5m2)
    bscal = jnp.array([0.5, 2.0, 8.0, 0.125, 0.7, 1.5, 0.3, 0.8, 0.9,
                       0.05], jnp.float32)
    bkw = dict(mask_mode="causal", fmt_s="e4m3", fmt_p="e4m3",
               fmt_e="e5m2", rounding_s="sr", rounding_p="sr",
               rounding_e="sr", saturate_e=False)
    outs = fp8_attention_bwd(q8, k8, v8, do8, seed, bscal, interpret=True,
                             **bkw)
    refs = fp8_attention_bwd_ref(q8, k8, v8, do8, seed, bscal, **bkw)
    bwd_eq = all(bool((np.asarray(a) == np.asarray(r)).all())
                 for a, r in zip(outs[:3], refs[:3])) \
        and float(outs[3]) == float(refs[3]) \
        and float(outs[4]) == float(refs[4])

    # Modeled HBM traffic the kernel eliminates: per score element the
    # unfused forward moves S f32 (4w+4r) + S8 (1w+1r) + P f32 (4w+4r) +
    # P8 (1w+1r) = 20 bytes; fused moves none of it.
    sp_bytes = b * h * s * s * 20
    out_bytes = b * h * s * d * 2
    return {
        "shape_bhsd": [b, h, s, d],
        "seq_len": s,
        "unfused_us": unfused_us,
        "fused_us": fused_us,
        "fused_two_pass_us": two_pass_us,
        "fused_tuned_us": tuned_us,
        "tuned_blocks_qkv": list(tuned),
        "default_blocks_qkv": list(dflt),
        "one_pass_vs_two_pass_wall_ratio":
            two_pass_us / max(fused_us, 1e-9),
        "tuned_vs_default_ratio": fused_us / max(tuned_us, 1e-9),
        "fused_vs_unfused_wall_ratio": unfused_us / max(tuned_us, 1e-9),
        "fwd_bit_parity": fwd_eq,
        "bwd_bit_parity": bwd_eq,
        "model_sp_hbm_bytes_saved": sp_bytes,
        "model_sp_vs_output_bytes_ratio": sp_bytes / out_bytes,
    }


def bench_attention_long(*, smoke=False):
    """Long-context sliding-window attention: the stripe-skip win.

    The unfused composition materializes the FULL (S, S) score/prob
    matrices (masking happens after the quantized scores exist — the
    `_sdpa` dataflow), so its work and HBM traffic are O(S^2) however
    narrow the window. The streamed-KV kernel only touches the
    ~(window + block_kv)/S fraction of kv stripes its block index maps
    visit; the XLA wall analogue mirrors that dataflow exactly — one
    jitted program whose per-q-chunk band covers just the
    `kv_stripe_span` stripes, vs four separately-jitted full-matrix
    passes with materialized S/P (same methodology as `bench_attention`).
    Parity of the real Pallas kernels is checked in interpret mode at a
    reduced windowed long-context shape (payload-free oracle). Keys are
    seq-length-suffixed so these entries never overwrite the short-seq
    baseline in the BENCH trajectory."""
    from repro.kernels.fp8_attention import (fp8_attention_fwd,
                                             fp8_attention_fwd_ref,
                                             kv_stripe_span)
    s, window, cq = (4096, 512, 512) if smoke else (8192, 1024, 1024)
    b, h, d = 1, 1, 64
    nk = s // cq
    q8, k8, v8 = [(jax.random.normal(jax.random.PRNGKey(i), (b, h, s, d))
                   * 0.3).astype(jnp.float8_e4m3fn) for i in range(3)]
    scal = jnp.array([0.5, 2.0, 8.0, 0.25], jnp.float32)
    fmt = get_format("e4m3")
    rows = jnp.arange(s)[:, None]
    cols = jnp.arange(s)[None, :]
    mask = (cols <= rows) & (cols > rows - window)

    # Unfused: four separately-jitted O(S^2) passes, materialized S/P.
    scores = jax.jit(lambda q, k: jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
        preferred_element_type=jnp.float32))
    qpass_s = jax.jit(lambda y: quantize_rne(y * scal[0], fmt))
    softq = jax.jit(lambda s8: quantize_rne(
        jax.nn.softmax(jnp.where(mask, s8.astype(jnp.float32) * scal[1],
                                 -1e30), axis=-1) * scal[2], fmt))
    pv = jax.jit(lambda p8, v: jnp.einsum(
        "bhqk,bhkd->bhqd", p8.astype(jnp.bfloat16),
        v.astype(jnp.bfloat16), preferred_element_type=jnp.float32)
        * scal[3])

    def unfused(q, k, v):
        y = scores(q, k)
        s8 = qpass_s(y)
        p8 = softq(s8)
        return pv(p8, v)

    # Streamed analogue: ONE jitted program; each q chunk touches only its
    # kv_stripe_span band — the work the kernel's index maps actually do.
    def streamed(q, k, v):
        outs = []
        for iq in range(s // cq):
            jmin, jmax = kv_stripe_span(iq * cq, cq, block_kv=cq, n_kv=nk,
                                        mask_mode="causal", window=window)
            k0, k1 = jmin * cq, (jmax + 1) * cq
            qc = q[:, :, iq * cq:(iq + 1) * cq].astype(jnp.bfloat16)
            y = jnp.einsum("bhqd,bhkd->bhqk", qc,
                           k[:, :, k0:k1].astype(jnp.bfloat16),
                           preferred_element_type=jnp.float32)
            s8 = quantize_rne(y * scal[0], fmt)
            r = iq * cq + jnp.arange(cq)[:, None]
            c = k0 + jnp.arange(k1 - k0)[None, :]
            bm = (c <= r) & (c > r - window)
            p8 = quantize_rne(jax.nn.softmax(
                jnp.where(bm, s8.astype(jnp.float32) * scal[1], -1e30),
                axis=-1) * scal[2], fmt)
            outs.append(jnp.einsum(
                "bhqk,bhkd->bhqd", p8.astype(jnp.bfloat16),
                v[:, :, k0:k1].astype(jnp.bfloat16),
                preferred_element_type=jnp.float32) * scal[3])
        return jnp.concatenate(outs, axis=2)

    streamed_j = jax.jit(streamed)
    iters = 2 if smoke else 3
    unfused(q8, k8, v8)
    unfused_us = float("inf")
    for _ in range(3):
        t0 = time.time()
        for _ in range(iters):
            out_u = unfused(q8, k8, v8)
        jax.block_until_ready(out_u)
        unfused_us = min(unfused_us, (time.time() - t0) / iters * 1e6)
    fused_us = min(timed(streamed_j, q8, k8, v8, iters=iters)
                   for _ in range(3))

    # Real-kernel interpret parity at a reduced windowed long-context
    # shape (payload-free oracle keeps memory flat).
    ps, pw, pb = 2048, 384, 512
    pq, pk, pv_ = [x[:, :, :ps] for x in (q8, k8, v8)]
    kw = dict(mask_mode="causal", window=pw, fmt_s="e4m3", fmt_p="e4m3",
              rounding_s="sr", rounding_p="sr")
    o, a_s, a_p = fp8_attention_fwd(pq, pk, pv_, jnp.uint32(3), scal,
                                    block_q=pb, block_kv=pb,
                                    interpret=True, **kw)
    ro, rs, rp, _, _ = fp8_attention_fwd_ref(pq, pk, pv_, jnp.uint32(3),
                                             scal, block_q=pb, block_kv=pb,
                                             payload=False, **kw)
    parity = bool((np.asarray(o).view(np.uint8)
                   == np.asarray(ro).view(np.uint8)).all()) \
        and float(a_s) == float(rs) and float(a_p) == float(rp)

    spans = [kv_stripe_span(i * cq, cq, block_kv=cq, n_kv=nk,
                            mask_mode="causal", window=window)
             for i in range(s // cq)]
    visited = sum(hi - lo + 1 for lo, hi in spans)
    pre = f"attention_s{s}_w{window}"
    return {
        f"{pre}_shape_bhsd": [b, h, s, d],
        f"{pre}_seq_len": s,
        f"{pre}_window": window,
        f"{pre}_unfused_us": unfused_us,
        f"{pre}_fused_us": fused_us,
        f"{pre}_fused_vs_unfused_wall_ratio":
            unfused_us / max(fused_us, 1e-9),
        f"{pre}_stripes_visited_frac": visited / ((s // cq) * nk),
        f"{pre}_interp_parity_s2048_windowed": parity,
        # Full (S,S) S/P round-trips the unfused path moves vs zero:
        f"{pre}_model_sp_hbm_bytes_saved": b * h * s * s * 20,
    }


def bench_autotune_sweep(*, smoke=False):
    """Run the block-size autotuner sweep (writes the winners table the
    benches below then consult) and flatten its per-key report into the
    BENCH trajectory: every swept key records its tuned blocks, tuned and
    default walls, and the tuned-vs-default ratio (>= 1.0 by construction
    — the default is always in the candidate set)."""
    from repro.kernels import autotune as at
    rows = at.run_sweep(smoke=smoke, log=lambda *a: None)
    out = {}
    for row in rows:
        key = row["key"].replace(".", "_")
        out[f"autotune_{key}_tuned_vs_default"] = row["tuned_vs_default"]
        out[f"autotune_{key}_wall_us"] = row["wall_us"]
        out[f"autotune_{key}_default_wall_us"] = row["default_wall_us"]
        if "bm" in row:
            out[f"autotune_{key}_blocks"] = [row["bm"], row["bk"],
                                             row["bn"]]
        else:
            out[f"autotune_{key}_blocks"] = [row["block_q"],
                                             row["block_kv"]]
        out[f"autotune_{key}_parity"] = row["parity"]
        # VMEM-model prune record (no silent caps): which candidates the
        # sweep refused to time, with the modeled footprints.
        out[f"autotune_{key}_pruned"] = row.get("pruned", [])
    return out


def bench_kernels(*, smoke=False):
    out = {}
    # Sweep first: bench_fused_vs_unfused / bench_attention consult the
    # winners table the sweep just wrote.
    out.update(bench_autotune_sweep(smoke=smoke))
    key = jax.random.PRNGKey(0)
    side = 256 if smoke else 1024
    x = jax.random.normal(key, (side, side), jnp.float32)

    rne = jax.jit(lambda v: quantize_rne(v))
    out["quantize_rne_us"] = timed(rne, x)
    sr = jax.jit(lambda v, k: quantize_sr_e5m2(v, k))
    out["quantize_sr_us"] = timed(sr, x, key)

    a8 = x.astype(jnp.float8_e5m2)
    b8 = jax.random.normal(key, (side, side // 2), jnp.float32).astype(
        jnp.float8_e5m2)
    ref = jax.jit(lambda a, b: fp8_matmul_ref(a, b))
    out["fp8_matmul_xla_us"] = timed(ref, a8, b8)

    # Pallas interpret-mode correctness (wall time is interpreter overhead;
    # recorded for completeness only).
    errs = []
    shapes = [(64, 256, 128)] if smoke else [(64, 256, 128), (128, 512, 256)]
    for m, k, n in shapes:
        a = jax.random.normal(jax.random.PRNGKey(1), (m, k)).astype(
            jnp.float8_e5m2)
        b = jax.random.normal(jax.random.PRNGKey(2), (k, n)).astype(
            jnp.float8_e5m2)
        y = fp8_matmul(a, b, bm=64, bk=128, bn=128, interpret=True)
        r = fp8_matmul_ref(a, b)
        errs.append(float(jnp.abs(y - r).max()))
    out["pallas_interpret_max_abs_err"] = max(errs)

    fv = bench_fused_vs_unfused(m=256 if smoke else 512,
                                k=256 if smoke else 512,
                                n=256 if smoke else 512)
    out.update({f"fused_epilogue_{k}": v for k, v in fv.items()})
    # The s=256-class GEMM is covered by the autotune_gemm_*_m256 sweep
    # entries above (tuned-vs-default, parity-gated); a fused-vs-unfused
    # wall A/B at 256^3 is a statistical tie on this host (the f32
    # intermediate is cache-resident, so the dataflows differ by one
    # dispatch) and recording it would log noise into the trajectory.
    out.update(bench_pallas_sweep(smoke=smoke))
    at = bench_attention(smoke=smoke)
    out.update({f"attention_{k}": v for k, v in at.items()})
    out.update(bench_attention_long(smoke=smoke))
    save_bench("kernels", out)
    for k, v in out.items():
        print(f"kernels {k}: {v}")
    return out


def _resolved_attn_blocks(q, cfg, seq):
    """The (block_q, block_kv) the attention op resolves for this run —
    config knobs > autotune table > kernel defaults."""
    from repro.kernels import autotune as at
    from repro.kernels.fp8_attention import ref as attn_ref
    head_dim = cfg.d_model // cfg.n_heads
    bq, bkv = at.resolve_attn_blocks("fwd", "causal", seq, seq, head_dim,
                                     block_q=q.attn_block_q,
                                     block_kv=q.attn_block_kv,
                                     autotune=q.autotune)
    return bq, attn_ref.resolve_block_kv(seq, bkv)


def bench_speed(*, smoke=False):
    """Reduced-scale training throughput: post-compile step time + tokens/s
    of the small LM step (batch 8 x seq 32), timed on the jitted step
    directly so compile time never enters the measurement."""
    from repro.core.loss_scale import LossScaler
    from repro.core.precision_policy import PAPER_POLICY
    from repro.data import DataConfig, synthetic_lm_batches
    from repro.models.registry import build_config
    from repro.models.transformer import init_lm
    from repro.train.step import make_train_step
    from benchmarks.common import _mk_opt

    cfg = build_config("qwen2-1.5b", smoke=True).replace(
        vocab_size=128, policy=PAPER_POLICY, remat=False,
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128)
    opt = _mk_opt("adam", 3e-3, LossScaler(mode="enhanced", init_scale=512.0,
                                           min_scale_schedule=()))
    step_fn = jax.jit(make_train_step(cfg, opt))
    batch_size, seq = 8, 32
    data = synthetic_lm_batches(DataConfig(vocab_size=128, seq_len=seq,
                                           batch_size=batch_size, seed=0))
    params = init_lm(jax.random.PRNGKey(0), cfg)
    state = opt.init(params)
    batch = next(data)
    state, _ = step_fn(state, batch, jax.random.PRNGKey(1))   # compile
    jax.block_until_ready(state.master)
    steps = 5 if smoke else 25
    # Per-step wall times (sync each step) so the artifact records the
    # p50/p99 span the health dashboard compares against, not just the
    # mean — a straggler tail is invisible in an aggregate-loop time.
    times = []
    t0 = time.time()
    for i in range(steps):
        ts = time.time()
        state, m = step_fn(state, next(data),
                           jax.random.fold_in(jax.random.PRNGKey(2), i))
        jax.block_until_ready(m)
        times.append(time.time() - ts)
    step_s = (time.time() - t0) / steps
    tokens_per_step = batch_size * seq
    q = cfg.policy.quant
    out = {
        "step_time_s": step_s,
        "step_time_p50_s": float(np.percentile(times, 50)),
        "step_time_p99_s": float(np.percentile(times, 99)),
        "tokens_per_s": tokens_per_step / step_s,
        "tokens_per_step": tokens_per_step,
        "steps_measured": steps,
        # The variant config the numbers were measured under — without it
        # the cross-PR trajectory is incomparable (a backend or recipe or
        # shape change would silently read as a perf change).
        "variant": {
            "backend": q.backend,
            "recipe": q.recipe,
            "scaling": q.scaling,
            "fuse_epilogue": q.fuse_epilogue,
            "fuse_attention": q.fuse_attention,
            # Config values (None = autotuned) plus the blocks the kernels
            # actually resolved for this run's attention shape.
            "attn_block_q": q.attn_block_q,
            "attn_block_kv": q.attn_block_kv,
            "autotune": q.autotune,
            "attn_blocks_resolved": list(_resolved_attn_blocks(q, cfg,
                                                               seq)),
            "batch_size": batch_size,
            "seq_len": seq,
            "model": {"arch": "qwen2-1.5b(smoke)", "n_layers": cfg.n_layers,
                      "d_model": cfg.d_model, "n_heads": cfg.n_heads,
                      "n_kv_heads": cfg.n_kv_heads, "d_ff": cfg.d_ff,
                      "vocab_size": cfg.vocab_size, "remat": cfg.remat},
        },
    }
    save_bench("train_speed", out)
    for k, v in out.items():
        print(f"train_speed {k}: {v}")
    return out


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    smoke = "--smoke" in argv
    bench_kernels(smoke=smoke)
    bench_speed(smoke=smoke)


if __name__ == "__main__":
    main()
