"""Kernel micro-bench: XLA-path FP8 ops wall time on CPU (correctness-scale;
TPU numbers come from the dry-run roofline, not wall time), a fused-vs-
unfused quantize-epilogue comparison, and a shape/layout sweep of the Pallas
kernels in interpret mode.

Emits the repo-root BENCH_kernels.json / BENCH_train_speed.json perf
trajectory (see benchmarks.common.save_bench).

  PYTHONPATH=src python -m benchmarks.kernel_bench [--smoke]
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_bench, timed
from repro.core.quantize import (fp8_amax_bits, quantize_rne,
                                 quantize_sr_e5m2, sr_fp8_via_f16)
from repro.core.fp8_formats import get_format
from repro.kernels.fp8_matmul import fp8_matmul, fp8_matmul_ref
from repro.kernels.fused_quant_matmul import (fused_quant_matmul,
                                              fused_quant_matmul_ref)


def bench_fused_vs_unfused(*, m=512, k=512, n=512, iters=10):
    """Fused quantize-in-epilogue GEMM vs the unfused composition.

    On CPU the comparison runs the XLA analogue of the two dataflows: the
    unfused side is three separately-jitted passes (GEMM -> materialize f32
    -> Q pass -> amax pass), forcing the output round-trip the fused
    epilogue eliminates; the fused side is one jitted program computing
    GEMM + Q + amax in a single fusion. The ratio is the headline
    fused-vs-unfused number of the BENCH trajectory (TPU wall time comes
    from the roofline dry-run, where the fused path additionally removes
    5 bytes/element of HBM epilogue traffic)."""
    a8 = (jax.random.normal(jax.random.PRNGKey(0), (m, k)) * 0.25).astype(
        jnp.float8_e5m2)
    b8 = (jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.1).astype(
        jnp.float8_e5m2)
    key = jax.random.PRNGKey(2)
    scale = jnp.float32(2.0)
    rand8 = jax.random.bits(key, (m, n), jnp.uint8)
    fmt = get_format("e5m2")

    gemm = jax.jit(lambda a, b: fp8_matmul_ref(a, b))
    qpass = jax.jit(lambda y, r: sr_fp8_via_f16(y * (1.0 / scale), r, fmt))
    apass = jax.jit(lambda q: fp8_amax_bits(q) * scale)

    def unfused(a, b, r):
        # Three separate jitted programs: each consumer reads its producer's
        # materialized output buffer — the HBM round-trips the fused
        # epilogue eliminates. No host syncs inside (those would only
        # measure dispatch latency); the timing loop syncs once at the end.
        y = gemm(a, b)        # materialize f32 output
        q = qpass(y, r)       # separate Q pass
        amax = apass(q)       # separate amax pass
        return q, amax

    fused = jax.jit(lambda a, b, r: fused_quant_matmul_ref(
        a, b, r, scale.reshape((1,)), with_amax=True))

    unfused(a8, b8, rand8)  # compile
    t0 = time.time()
    for _ in range(iters):
        out_u = unfused(a8, b8, rand8)
    jax.block_until_ready(out_u)
    unfused_us = (time.time() - t0) / iters * 1e6

    fused_us = timed(fused, a8, b8, rand8, iters=iters)

    q_u, amax_u = out_u
    q_f, amax_f = fused(a8, b8, rand8)
    return {
        "shape_mkn": [m, k, n],
        "unfused_us": unfused_us,
        "fused_us": fused_us,
        "fused_vs_unfused_gemm_ratio": unfused_us / max(fused_us, 1e-9),
        "bitwise_equal": bool(
            (np.asarray(q_u).view(np.uint8)
             == np.asarray(q_f).view(np.uint8)).all()),
        # ref's fused amax is in grid units; the unfused amax pass de-scales.
        "amax_equal": float(amax_u) == float(amax_f * scale),
        # The quantity the fused kernel actually optimizes (CPU wall time
        # cannot model it): HBM bytes the epilogue moves per element —
        # unfused writes the f32 GEMM output, re-reads it for the Q pass
        # and writes fp8 (4+4+1) vs the fused kernel's single fp8 write.
        "model_epilogue_hbm_bytes_ratio": 9.0,
    }


def bench_pallas_sweep(*, smoke=False):
    """Interpret-mode bit-parity sweep of the fused kernel's three GEMM
    layouts (fwd nn / dgrad nt / wgrad tn) against the unfused composition
    oracle — wall time is interpreter overhead; the recorded signal is the
    parity bits."""
    shapes = [(64, 256, 128)] if smoke else [(64, 256, 128), (100, 300, 130)]
    out = {}
    for m, k, n in shapes:
        for dims, ash, bsh in [("nn", (m, k), (k, n)),
                               ("nt", (m, k), (n, k)),
                               ("tn", (k, m), (k, n))]:
            a = (jax.random.normal(jax.random.PRNGKey(0), ash) * 0.25
                 ).astype(jnp.float8_e5m2)
            b = (jax.random.normal(jax.random.PRNGKey(1), bsh) * 0.1
                 ).astype(jnp.float8_e5m2)
            key = jax.random.PRNGKey(2)
            y, amax = fused_quant_matmul(
                a, b, key, jnp.array([2.0]), dims=dims, bm=32, bk=128,
                bn=128, rounding="sr", with_amax=True, amax_units="grid",
                interpret=True)
            rand8 = jax.random.bits(key, y.shape, jnp.uint8)
            ref, ramax = fused_quant_matmul_ref(
                a, b, rand8, jnp.array([2.0]), dims=dims, rounding="sr",
                with_amax=True)
            bit_eq = bool((np.asarray(y).view(np.uint8)
                           == np.asarray(ref).view(np.uint8)).all())
            out[f"{dims}_{m}x{k}x{n}_bit_equal"] = bit_eq \
                and float(amax) == float(ramax)
    return out


def bench_kernels(*, smoke=False):
    out = {}
    key = jax.random.PRNGKey(0)
    side = 256 if smoke else 1024
    x = jax.random.normal(key, (side, side), jnp.float32)

    rne = jax.jit(lambda v: quantize_rne(v))
    out["quantize_rne_us"] = timed(rne, x)
    sr = jax.jit(lambda v, k: quantize_sr_e5m2(v, k))
    out["quantize_sr_us"] = timed(sr, x, key)

    a8 = x.astype(jnp.float8_e5m2)
    b8 = jax.random.normal(key, (side, side // 2), jnp.float32).astype(
        jnp.float8_e5m2)
    ref = jax.jit(lambda a, b: fp8_matmul_ref(a, b))
    out["fp8_matmul_xla_us"] = timed(ref, a8, b8)

    # Pallas interpret-mode correctness (wall time is interpreter overhead;
    # recorded for completeness only).
    errs = []
    shapes = [(64, 256, 128)] if smoke else [(64, 256, 128), (128, 512, 256)]
    for m, k, n in shapes:
        a = jax.random.normal(jax.random.PRNGKey(1), (m, k)).astype(
            jnp.float8_e5m2)
        b = jax.random.normal(jax.random.PRNGKey(2), (k, n)).astype(
            jnp.float8_e5m2)
        y = fp8_matmul(a, b, bm=64, bk=128, bn=128, interpret=True)
        r = fp8_matmul_ref(a, b)
        errs.append(float(jnp.abs(y - r).max()))
    out["pallas_interpret_max_abs_err"] = max(errs)

    fv = bench_fused_vs_unfused(m=256 if smoke else 512,
                                k=256 if smoke else 512,
                                n=256 if smoke else 512)
    out.update({f"fused_epilogue_{k}": v for k, v in fv.items()})
    out.update(bench_pallas_sweep(smoke=smoke))
    save_bench("kernels", out)
    for k, v in out.items():
        print(f"kernels {k}: {v}")
    return out


def bench_speed(*, smoke=False):
    """Reduced-scale training throughput: post-compile step time + tokens/s
    of the small LM step (batch 8 x seq 32), timed on the jitted step
    directly so compile time never enters the measurement."""
    from repro.core.loss_scale import LossScaler
    from repro.core.precision_policy import PAPER_POLICY
    from repro.data import DataConfig, synthetic_lm_batches
    from repro.models.registry import build_config
    from repro.models.transformer import init_lm
    from repro.train.step import make_train_step
    from benchmarks.common import _mk_opt

    cfg = build_config("qwen2-1.5b", smoke=True).replace(
        vocab_size=128, policy=PAPER_POLICY, remat=False,
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128)
    opt = _mk_opt("adam", 3e-3, LossScaler(mode="enhanced", init_scale=512.0,
                                           min_scale_schedule=()))
    step_fn = jax.jit(make_train_step(cfg, opt))
    batch_size, seq = 8, 32
    data = synthetic_lm_batches(DataConfig(vocab_size=128, seq_len=seq,
                                           batch_size=batch_size, seed=0))
    params = init_lm(jax.random.PRNGKey(0), cfg)
    state = opt.init(params)
    batch = next(data)
    state, _ = step_fn(state, batch, jax.random.PRNGKey(1))   # compile
    jax.block_until_ready(state.master)
    steps = 5 if smoke else 25
    t0 = time.time()
    for i in range(steps):
        state, m = step_fn(state, next(data),
                           jax.random.fold_in(jax.random.PRNGKey(2), i))
    jax.block_until_ready(m)
    step_s = (time.time() - t0) / steps
    tokens_per_step = batch_size * seq
    out = {
        "step_time_s": step_s,
        "tokens_per_s": tokens_per_step / step_s,
        "tokens_per_step": tokens_per_step,
        "steps_measured": steps,
    }
    save_bench("train_speed", out)
    for k, v in out.items():
        print(f"train_speed {k}: {v}")
    return out


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    smoke = "--smoke" in argv
    bench_kernels(smoke=smoke)
    bench_speed(smoke=smoke)


if __name__ == "__main__":
    main()
